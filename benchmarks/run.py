# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
# Simulator sections run as declarative Sweeps on the parallel sweep engine
# (docs/SWEEPS.md) and merge their grids into BENCH_sim.json at the repo
# root.  ``--quick`` shrinks every grid for CI smoke runs; ``--only`` selects
# sections by name; ``--list`` prints the registered policies, workloads,
# and sections without running anything.
from __future__ import annotations

import os
import sys
import time

# support both `python -m benchmarks.run` and `python benchmarks/run.py`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def list_registries(section_names) -> None:
    """--list: the registered policies (component matrix), workloads
    (metadata), and benchmark sections."""
    from repro.capture import CAPTURED, capture_meta
    from repro.core.sim import (
        available_controllers,
        available_placements,
        available_policies,
        available_topologies,
        available_workloads,
        build_topology,
        compressibility_of,
        get_controller,
        get_placement,
        get_policy,
        get_workload,
        topology_description,
    )
    from repro.core.sim.config import SimConfig

    print("policies (name: granularity/partitioning/up-uplink/compression"
          "/throttle[/flags]):")
    for name in available_policies():
        p = get_policy(name)
        flags = []
        if p.free_transfers:
            flags.append("free")
        if not p.page_carries_requests:
            flags.append("race")
        if p.line_share is not None:
            flags.append(f"line_share={p.line_share}")
        if p.fabric is not None:
            flags.append(f"fab-{p.fabric}")
        comp = "/".join([p.granularity, p.partitioning,
                         f"up-{p.uplink_partitioning}", p.compression,
                         "throttle" if p.throttle else "nothrottle"]
                        + flags)
        print(f"  {name:18s} {comp:44s} {p.description}")
    print("workloads (name: compressibility, description):")
    for name in available_workloads():
        if name in CAPTURED:
            continue  # listed below with full source-kernel metadata
        w = get_workload(name)
        print(f"  {name:18s} x{compressibility_of(name):<4.1f} {w.description}")
    print("captured kernel workloads (source-kernel metadata, DESIGN.md §2.8):")
    for name in CAPTURED:
        m = capture_meta(name)
        grid = "x".join(str(g) for g in m["grid"])
        print(f"  {name:18s} {m['kernel']}/{m['variant']:8s} grid={grid:10s} "
              f"{m['n_accesses']} accesses, "
              f"{m['footprint'] >> 10} KiB footprint, "
              f"x{m['compressibility']:.2f} measured, "
              f"operands={','.join(m['operands'])}")
    print("controllers (name: thresholds, description — DESIGN.md §2.12):")
    _cfg = SimConfig()
    for name in available_controllers():
        c = get_controller(name)(_cfg)
        th = ",".join(f"{k}={v}" for k, v in sorted(c.thresholds().items()))
        print(f"  {name:18s} {th:44s} {c.description}")
    print("placements (name: allocator, description — DESIGN.md §2.13):")
    for name in available_placements():
        p = get_placement(name)
        print(f"  {name:18s} {p.allocator:44s} {p.description}")
    print("topologies (name: ports/hops at 2 CCs x 2 MCs, description — "
          "DESIGN.md §2.11):")
    for name in available_topologies():
        spec = build_topology(name, n_ccs=2, n_mcs=2)
        hops = len(spec.down_paths[(0, 0)])
        print(f"  {name:18s} {len(spec.ports)} ports, {hops} hop"
              f"{'s' if hops != 1 else ''}  {topology_description(name)}")
    print("sections:")
    print("  " + ",".join(section_names))


def main() -> None:
    import argparse

    from benchmarks import (
        bench_kernels,
        engine_bench,
        fig2_schemes,
        fig4_multijob,
        fig4_robustness,
        fig5_scalability,
        fig6_ablation,
        fig7_uplink,
        fig8_kernels,
        fig9_serving,
        fig10_topology,
        fig11_controllers,
        fig12_memside,
        roofline,
    )

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny grids (CI smoke): 5-10x fewer simulated accesses")
    ap.add_argument("--only", default="",
                    help="comma-separated section names to run")
    ap.add_argument("--workers", type=int, default=None,
                    help="sweep worker processes (default: all cores)")
    ap.add_argument("--engine", choices=("python", "batch"), default="python",
                    help="sweep cell engine: per-cell oracle event loop or "
                         "the lockstep batch core (bit-identical; uncovered "
                         "cells fall back to the oracle automatically)")
    ap.add_argument("--list", action="store_true",
                    help="print registered policies, workloads, and sections")
    args = ap.parse_args()

    n_fig2 = 2_000 if args.quick else 20_000
    n_fig4 = 1_500 if args.quick else 15_000
    # fig6 needs >= 1000 accesses/thread so the 'ph' workload actually
    # alternates phases (epoch = 500 accesses)
    n_fig6 = 4_000 if args.quick else 20_000
    # fig7 needs >= 1000 accesses/thread so the 'wh' workload actually
    # churns its local page cache (writebacks are the traffic under test)
    n_fig7 = 4_000 if args.quick else 20_000
    # fig8 needs >= 2000 accesses/thread so a captured-kernel replay window
    # spans several tile bursts (the inter-tile jumps are the structure
    # under test; one flash tile alone is ~512 line accesses)
    n_fig8 = 8_000 if args.quick else 40_000
    # fig9 quick shrinks the request count AND the per-phase slice sizes
    # (request latency scales with phase length, so the quick grid stays
    # deep in the same load regimes at ~1/4 the simulated accesses)
    fig9_kw = (dict(n_requests=24, prefill_accesses=512, decode_steps=3,
                    decode_accesses=128) if args.quick
               else dict(n_requests=96, prefill_accesses=1024,
                         decode_steps=4, decode_accesses=256))
    # fig10 needs >= 1000 accesses/thread so pointer-chase demand misses
    # and the streaming bulk actually overlap on the shared trunks
    n_fig10 = 4_000 if args.quick else 20_000
    # fig11 reuses the fig6/fig7 grid sizing for its synthetic halves and
    # 2x that for the captured-kernel half (fig8's sizing rationale)
    n_fig11 = 4_000 if args.quick else 20_000
    # fig12 needs >= 1000 accesses/thread so the finite pools actually fill
    # (capacity pressure and eviction churn are the dynamics under test)
    n_fig12 = 4_000 if args.quick else 20_000
    w = args.workers
    eng = args.engine
    sections = [
        ("fig2", lambda: fig2_schemes.run(n_accesses=n_fig2, workers=w, engine=eng)),
        ("fig4_top", lambda: fig4_robustness.run(n_accesses=n_fig4, workers=w, engine=eng)),
        ("fig4_bottom", lambda: fig4_multijob.run(n_accesses=n_fig4, workers=w, engine=eng)),
        ("sweep_jitter", lambda: fig4_robustness.run_jitter(n_accesses=n_fig4, workers=w, engine=eng)),
        ("sweep_nmcs", lambda: fig4_robustness.run_nmcs(n_accesses=n_fig4, workers=w, engine=eng)),
        ("fig5", lambda: fig5_scalability.run(n_accesses=n_fig4, workers=w, engine=eng)),
        ("fig6", lambda: fig6_ablation.run(n_accesses=n_fig6, workers=w, engine=eng)),
        ("fig7", lambda: fig7_uplink.run(n_accesses=n_fig7, workers=w, engine=eng)),
        ("fig7_wshare", lambda: fig7_uplink.run_wshare(n_accesses=n_fig7, workers=w, engine=eng)),
        ("fig8", lambda: fig8_kernels.run(n_accesses=n_fig8, workers=w, engine=eng)),
        ("fig9", lambda: fig9_serving.run(workers=w, engine=eng, **fig9_kw)),
        ("fig10", lambda: fig10_topology.run(n_accesses=n_fig10, workers=w, engine=eng)),
        ("fig11", lambda: fig11_controllers.run(n_accesses=n_fig11, workers=w, engine=eng)),
        ("fig12", lambda: fig12_memside.run(n_accesses=n_fig12, workers=w, engine=eng)),
        ("engine_bench", lambda: engine_bench.run(n_accesses=n_fig2)),
        ("kernels", bench_kernels.run),
        ("roofline", roofline.run),
    ]
    # opt-in sections: run only when explicitly named in --only (the
    # seed-axis variance grid is ~6x a fig6 run — nightly.yml selects it;
    # a bare `run.py` keeps the canonical ledger sections)
    optin = [
        ("fig6_var", lambda: fig6_ablation.run_variance(n_accesses=n_fig6, workers=w, engine=eng)),
    ]
    section_names = [s[0] for s in sections] + [s[0] for s in optin]
    if args.list:
        list_registries(section_names)
        return
    if args.only:
        keep = {s.strip() for s in args.only.split(",") if s.strip()}
        unknown = keep - set(section_names)
        if unknown:
            sys.exit(f"unknown --only section(s) {sorted(unknown)}; "
                     f"choose from {sorted(section_names)} "
                     f"(see `PYTHONPATH=src python -m benchmarks.run --list`)")
        sections = [s for s in sections + optin if s[0] in keep]

    print("name,us_per_call,derived")
    failures = 0
    t_all = time.perf_counter()
    for name, fn in sections:
        t0 = time.perf_counter()
        try:
            for tag, us, derived in fn():
                print(f"{tag},{us:.1f},{derived}")
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
        # per-section wall-clock on stderr: the ledger carries the same
        # numbers as non-gated wall_* keys (docs/SWEEPS.md)
        print(f"[wall] {name}: {time.perf_counter() - t0:.2f}s",
              file=sys.stderr)
    print(f"[wall] total ({args.engine} engine): "
          f"{time.perf_counter() - t_all:.2f}s", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
