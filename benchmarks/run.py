# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import bench_kernels, fig2_schemes, fig4_multijob, fig4_robustness, roofline

    print("name,us_per_call,derived")
    sections = [
        ("fig2", fig2_schemes.run),
        ("fig4_top", fig4_robustness.run),
        ("fig4_bottom", fig4_multijob.run),
        ("kernels", bench_kernels.run),
        ("roofline", roofline.run),
    ]
    failures = 0
    for name, fn in sections:
        try:
            for tag, us, derived in fn():
                print(f"{tag},{us:.1f},{derived}")
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
