"""Multi-CC scalability (DESIGN.md §2.5): N compute complexes, each running
a full application, contending for the shared per-MC downlinks — DaeMon vs
the page scheme as the system scales from 1 to 8 CCs.

One declarative Sweep over workload-mix x n_ccs x scheme on the parallel
sweep engine; the per-n_ccs daemon-vs-page geomeans merge into
BENCH_sim.json (docs/SWEEPS.md) and are gated in CI by check_bench.py.
The paper's scalability claim shows up as the geomean *increasing*
monotonically with the CC count: every added CC's page bursts queue on the
shared FIFO downlink, while DaeMon's reserved line share keeps critical
lines bounded.

Mix semantics: CC c runs parts[c % len(parts)], so a multi-part mix's
workload *composition* varies with n_ccs (at n_ccs=1 only the first part
runs).  Each page-vs-daemon ratio is composition-matched (both schemes see
identical traces at a cell), and the pure 'pr' mix gives the
composition-stable contention trend; the multi-part mixes add realism
(heterogeneous neighbors), not a controlled composition axis.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import (
    default_workers,
    fig5_scalability_spec,
    run_sweep,
    scheme_geomean,
    scheme_ratio,
    write_bench,
)

from benchmarks import BENCH_PATH


def run(n_accesses: int = 15_000, workers: int | None = None,
        engine: str = "python",
        bench_path: str = BENCH_PATH):
    workers = default_workers() if workers is None else workers
    sw = fig5_scalability_spec(n_accesses=n_accesses)
    res = run_sweep(sw, workers=workers, engine=engine)
    per_call = res.us_per_call  # per-cell sim cost, worker-count independent
    rows, derived = [], {}
    for n_ccs in sw.axes["n_ccs"]:
        sub = res.filter(n_ccs=n_ccs)
        g = scheme_geomean(sub)
        derived[f"daemon_vs_page_geomean@n_ccs={n_ccs}"] = g
        rows.append((f"fig5/n_ccs{n_ccs}/geomean_daemon_vs_page", per_call,
                     f"speedup={g:.3f}"))
        for key, ratio in sorted(scheme_ratio(sub).items()):
            mix = dict(key)["workload"]
            rows.append((f"fig5/{mix}/n_ccs{n_ccs}", per_call,
                         f"speedup={ratio:.3f}"))
    write_bench(bench_path, res, derived=derived)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-accesses", type=int, default=15_000)
    args = ap.parse_args()
    for tag, us, derived in run(args.n_accesses, args.workers):
        print(f"{tag},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
