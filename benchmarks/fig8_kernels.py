"""Captured-kernel grid (fig8, DESIGN.md §2.8): movement policies on the
Pallas kernels' own block-level memory streams.

The repro.capture subsystem derives deterministic traces from the kernels'
tiling geometry (no TPU needed) and registers them as workloads
(fa_prefill, fa_decode, mamba_fwd, bq_quant).  One declarative Sweep over
captured workload x link_bw_frac x {page, cacheline, daemon_fixed_gran,
daemon}; the per-kernel daemon-vs-page geomeans across the bandwidth range
merge into BENCH_sim.json under ``daemon_vs_page_geomean@kernel=<name>``
and are gated in CI by check_bench.py.

The headline: adaptive granularity behaves differently on real tiled
streams than on any synthetic source in the suite.  Tile fetches are
page-dense (high spatial reuse inside a tile, abrupt inter-tile jumps), so
the page scheme is already near-optimal — daemon's selection unit
correctly converges to page granularity (geomean ~1x, vs ~3x on the
synthetic suite) and pure line movement collapses to ~0.3-0.6x.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import (
    default_workers,
    fig8_kernels_spec,
    geomean,
    run_sweep,
    scheme_ratio,
    write_bench,
)

from benchmarks import BENCH_PATH


def run(n_accesses: int = 20_000, workers: int | None = None,
        engine: str = "python",
        bench_path: str = BENCH_PATH):
    workers = default_workers() if workers is None else workers
    sw = fig8_kernels_spec(n_accesses=n_accesses)
    res = run_sweep(sw, workers=workers, engine=engine)
    per_call = res.us_per_call  # per-cell sim cost, worker-count independent
    rows, derived = [], {}
    for w in sw.axes["workload"]:
        sub = res.filter(workload=w)
        g = geomean(scheme_ratio(sub).values())
        derived[f"daemon_vs_page_geomean@kernel={w}"] = g
        rows.append((f"fig8/{w}/geomean_daemon_vs_page", per_call,
                     f"speedup={g:.3f}"))
        for scheme in sw.axes["scheme"]:
            if scheme == "page":
                continue
            for key, ratio in sorted(
                    scheme_ratio(sub, den=scheme).items()):
                bw = dict(key)["link_bw_frac"]
                rows.append((f"fig8/{w}/bw{bw}/{scheme}", per_call,
                             f"speedup_vs_page={ratio:.3f}"))
    write_bench(bench_path, res, derived=derived)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-accesses", type=int, default=20_000)
    args = ap.parse_args()
    for tag, us, derived in run(args.n_accesses, args.workers):
        print(f"{tag},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
