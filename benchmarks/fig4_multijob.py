"""Paper Fig. 4 (bottom): multiple applications sharing one CC + MC — DaeMon
vs page under interference.  One Sweep over workload x scheme at n_jobs=4,
run on the parallel sweep engine and merged into BENCH_sim.json.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import default_workers, fig4_bottom_spec, run_sweep, scheme_geomean, write_bench

from benchmarks import BENCH_PATH

N_JOBS = 4


def run(n_accesses: int = 15_000, workers: int | None = None,
        engine: str = "python",
        bench_path: str = BENCH_PATH):
    workers = default_workers() if workers is None else workers
    sw = fig4_bottom_spec(workloads=("pr", "nw", "dr", "st"), n_jobs=N_JOBS,
                          n_accesses=n_accesses)
    res = run_sweep(sw, workers=workers, engine=engine)
    per_call = res.us_per_call  # per-cell sim cost, worker-count independent
    g = res.grid("workload", "scheme")
    rows = []
    for w in sw.axes["workload"]:
        mp, md = g[(w, "page")].metrics, g[(w, "daemon")].metrics
        rows.append(
            (
                f"fig4bot/{w}/jobs{N_JOBS}",
                per_call,
                f"speedup={mp.cycles / md.cycles:.3f};"
                f"cost_ratio={mp.avg_access_cost / max(md.avg_access_cost, 1e-9):.3f}",
            )
        )
    write_bench(bench_path, res,
                derived={"daemon_vs_page_geomean": scheme_geomean(res.rows),
                         "n_jobs": N_JOBS})
    return rows


if __name__ == "__main__":
    for tag, us, derived in run():
        print(f"{tag},{us:.1f},{derived}")
