"""Paper Fig. 4 (bottom): multiple applications sharing one CC + MC — DaeMon
vs page under interference."""
from __future__ import annotations

import time

from repro.core.sim import fig4_bottom


def run(n_accesses: int = 15_000):
    t0 = time.time()
    rows_raw = fig4_bottom(workloads=("pr", "nw", "dr", "st"), n_jobs=4,
                           n_accesses=n_accesses)
    per_call = (time.time() - t0) * 1e6 / max(len(rows_raw), 1)
    return [
        (
            f"fig4bot/{r['workload']}/jobs{r['n_jobs']}",
            per_call,
            f"speedup={r['speedup']:.3f};cost_ratio={r['access_cost_ratio']:.3f}",
        )
        for r in rows_raw
    ]
