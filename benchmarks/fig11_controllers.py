"""Movement-controller grid (fig11, DESIGN.md §2.12): the registered
controllers (fixed, adaptive, tuned) head-to-head inside the daemon scheme
on the three grids where the selection unit's decisions bind.

Three declarative Sweeps share the fig6/fig7/fig8 grid definitions with a
``controller`` axis added: the congested synthetic ablation suite
(fig11_ablation), the asymmetric-uplink write-heavy grid (fig11_uplink),
and the captured Pallas-kernel streams (fig11_kernels).  The derived
daemon-vs-page geomeans per controller merge into BENCH_sim.json under
``daemon_vs_page_geomean@ctrl=<c>`` / ``...@ctrl=<c>:grid=uplink`` /
``...@ctrl=<c>:kernel=<w>`` and are gated in CI by check_bench.py.

The headline: 'fixed' reproduces the legacy inline thresholds bit-for-bit
(its keys must match the controller-free fig6/7/8 geomeans), 'adaptive'
observes coalesce density and backs off line racing in page-dense phases —
buying back the captured kernel traces where fixed-threshold racing loses —
while staying within tolerance on the synthetics, and 'tuned' replays the
per-workload thresholds fitted offline by benchmarks/fit_controller.py.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import (
    default_workers,
    fig11_ablation_spec,
    fig11_geomeans,
    fig11_kernels_spec,
    fig11_uplink_spec,
    run_sweep,
    write_bench,
)

from benchmarks import BENCH_PATH


def run(n_accesses: int = 20_000, workers: int | None = None,
        engine: str = "python",
        bench_path: str = BENCH_PATH,
        n_kernel_accesses: int | None = None):
    workers = default_workers() if workers is None else workers
    if n_kernel_accesses is None:
        # kernel replays need longer windows than the synthetics (several
        # tile bursts; run.py uses 2x fig6's size for fig8 likewise)
        n_kernel_accesses = 2 * n_accesses
    ab_sw = fig11_ablation_spec(n_accesses=n_accesses)
    up_sw = fig11_uplink_spec(n_accesses=n_accesses)
    kn_sw = fig11_kernels_spec(n_accesses=n_kernel_accesses)
    ab = run_sweep(ab_sw, workers=workers, engine=engine)
    up = run_sweep(up_sw, workers=workers, engine=engine)
    kn = run_sweep(kn_sw, workers=workers, engine=engine)
    derived = fig11_geomeans(ab, up, kn)
    # each ledger entry carries the derived keys its own grid produced
    write_bench(bench_path, ab, derived={
        k: v for k, v in derived.items() if ":" not in k})
    write_bench(bench_path, up, derived={
        k: v for k, v in derived.items() if ":grid=uplink" in k})
    write_bench(bench_path, kn, derived={
        k: v for k, v in derived.items() if ":kernel=" in k})
    rows = []
    for res, tag in ((ab, "ablation"), (up, "uplink"), (kn, "kernels")):
        per_call = res.us_per_call
        for c in res.axes["controller"]:
            if tag == "ablation":
                keys = [f"daemon_vs_page_geomean@ctrl={c}"]
            elif tag == "uplink":
                keys = [f"daemon_vs_page_geomean@ctrl={c}:grid=uplink"]
            else:
                keys = [k for k in derived
                        if k.startswith(f"daemon_vs_page_geomean@ctrl={c}"
                                        ":kernel=")]
            for k in keys:
                suffix = k.split("@ctrl=", 1)[1]
                rows.append((f"fig11/{tag}/{suffix}", per_call,
                             f"speedup={derived[k]:.3f}"))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-accesses", type=int, default=20_000)
    ap.add_argument("--engine", choices=("python", "batch"),
                    default="python")
    args = ap.parse_args()
    for tag, us, derived in run(args.n_accesses, args.workers,
                                engine=args.engine):
        print(f"{tag},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
