"""Paper Fig. 4 (top): DaeMon's speedup over the page scheme across network
bandwidths, MC counts, and applications."""
from __future__ import annotations

import time

from repro.core.sim import fig4_top


def run(n_accesses: int = 15_000):
    t0 = time.time()
    rows_raw = fig4_top(
        workloads=("pr", "nw", "st", "ml"),
        bw_fracs=(0.5, 0.25, 0.125),
        n_mcs_list=(1, 2, 4),
        n_accesses=n_accesses,
    )
    per_call = (time.time() - t0) * 1e6 / max(len(rows_raw), 1)
    rows = []
    for r in rows_raw:
        rows.append(
            (
                f"fig4top/{r['workload']}/bw{r['bw_frac']}/mc{r['n_mcs']}",
                per_call,
                f"speedup={r['speedup']:.3f};cost_ratio={r['access_cost_ratio']:.3f}",
            )
        )
    return rows
