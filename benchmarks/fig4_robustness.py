"""Paper Fig. 4 (top) + scenario-axis sweeps: DaeMon's speedup over the page
scheme across network bandwidths, MC counts, and applications — plus the two
regimes the paper motivates but cannot grid serially: time-varying link
bandwidth (jitter) and multi-MC page interleaving.

Each grid is one declarative Sweep run on the parallel sweep engine; results
merge into BENCH_sim.json (docs/SWEEPS.md).
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import (
    SimConfig,
    Sweep,
    default_workers,
    fig4_top_spec,
    run_sweep,
    scheme_geomean,
    scheme_ratio,
    write_bench,
)

from benchmarks import BENCH_PATH

WORKLOADS = ("pr", "nw", "st", "ml")


def run(n_accesses: int = 15_000, workers: int | None = None,
        engine: str = "python",
        bench_path: str = BENCH_PATH):
    """Fig. 4 top: workload x link bandwidth x MC count, page vs daemon."""
    workers = default_workers() if workers is None else workers
    sw = fig4_top_spec(workloads=WORKLOADS, n_accesses=n_accesses)
    res = run_sweep(sw, workers=workers, engine=engine)
    per_call = res.us_per_call  # per-cell sim cost, worker-count independent
    g = res.grid("workload", "link_bw_frac", "n_mcs", "scheme")
    rows = []
    for w in sw.axes["workload"]:
        for bw in sw.axes["link_bw_frac"]:
            for n_mcs in sw.axes["n_mcs"]:
                mp = g[(w, bw, n_mcs, "page")].metrics
                md = g[(w, bw, n_mcs, "daemon")].metrics
                rows.append(
                    (
                        f"fig4top/{w}/bw{bw}/mc{n_mcs}",
                        per_call,
                        f"speedup={mp.cycles / md.cycles:.3f};"
                        f"cost_ratio={mp.avg_access_cost / max(md.avg_access_cost, 1e-9):.3f}",
                    )
                )
    write_bench(bench_path, res,
                derived={"daemon_vs_page_geomean": scheme_geomean(res.rows)})
    return rows


def _run_axis_sweep(sw: Sweep, axis: str, tag: str, derived_key: str,
                    workers: int | None, bench_path: str,
                    engine: str = "python"):
    """Shared body of the scenario-axis sections: run the sweep, report the
    daemon-vs-page geomean per value of ``axis`` (plus per-workload ratios),
    and merge into the ledger."""
    workers = default_workers() if workers is None else workers
    res = run_sweep(sw, workers=workers, engine=engine)
    per_call = res.us_per_call  # per-cell sim cost, worker-count independent
    rows, derived = [], {}
    for v in sw.axes[axis]:
        sub = res.filter(**{axis: v})
        g = scheme_geomean(sub)
        derived[f"daemon_vs_page_geomean@{derived_key}={v}"] = g
        rows.append((f"{tag}/{axis}{v}/geomean_daemon_vs_page", per_call,
                     f"speedup={g:.3f}"))
        for key, ratio in sorted(scheme_ratio(sub).items()):
            w = dict(key)["workload"]
            rows.append((f"{tag}/{w}/{axis}{v}", per_call,
                         f"speedup={ratio:.3f}"))
    write_bench(bench_path, res, derived=derived)
    return rows


def run_jitter(n_accesses: int = 15_000, workers: int | None = None,
               engine: str = "python",
               bench_path: str = BENCH_PATH):
    """Scenario axis (a): bandwidth jitter (fabric congestion).  Every link's
    available bandwidth dips each epoch (multiplier 1 - j*U[0,1)); DaeMon's
    decoupled queues should degrade less than the page FIFO as j grows."""
    sw = Sweep(
        name="sweep_jitter",
        axes={
            "workload": WORKLOADS,
            "bw_jitter": (0.0, 0.25, 0.5),
            "scheme": ("page", "daemon"),
        },
        base=SimConfig(link_bw_frac=0.125, jitter_period=20_000),
        n_accesses=n_accesses,
    )
    return _run_axis_sweep(sw, "bw_jitter", "jitter", "jitter",
                           workers, bench_path, engine=engine)


def run_nmcs(n_accesses: int = 15_000, workers: int | None = None,
             engine: str = "python",
             bench_path: str = BENCH_PATH):
    """Scenario axis (b): multi-MC scaling with hashed page interleaving —
    pages (and the line fetches into them) spread across n_mcs independent
    links instead of aliasing onto a few."""
    sw = Sweep(
        name="sweep_nmcs",
        axes={
            "workload": WORKLOADS,
            "n_mcs": (1, 2, 4),
            "scheme": ("page", "daemon"),
        },
        base=SimConfig(link_bw_frac=0.125, mc_interleave="hash"),
        n_accesses=n_accesses,
    )
    return _run_axis_sweep(sw, "n_mcs", "nmcs", "n_mcs", workers,
                           bench_path, engine=engine)


if __name__ == "__main__":
    for fn in (run, run_jitter, run_nmcs):
        for tag, us, derived in fn():
            print(f"{tag},{us:.1f},{derived}")
