"""Paper Fig. 2: data-movement overheads of each scheme, normalized to the
monolithic `local` configuration, per workload.

The whole figure is ONE declarative Sweep (docs/SWEEPS.md) executed by the
process-pool sweep engine; results merge into BENCH_sim.json at the repo
root.  ``python benchmarks/fig2_schemes.py --compare`` runs the same grid
serially and in parallel, asserts cell-for-cell identical Metrics, and
reports the wall-clock speedup.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import (
    SCHEMES,
    SimConfig,
    Sweep,
    default_workers,
    fig2_spec,
    run_sweep,
    scheme_geomean,
    write_bench,
)

from benchmarks import BENCH_PATH

WORKLOADS = ("pr", "bf", "ts", "nw", "dr", "pf", "st", "ml")


def build_sweep(n_accesses: int = 20_000, link_bw_frac: float = 0.25) -> Sweep:
    """The canonical fig2 grid (runner.fig2_spec) at benchmark sizes."""
    return fig2_spec(SimConfig(link_bw_frac=link_bw_frac),
                     workloads=WORKLOADS, n_accesses=n_accesses)


def run(n_accesses: int = 20_000, link_bw_frac: float = 0.25,
        workers: int | None = None, engine: str = "python",
        bench_path: str = BENCH_PATH):
    workers = default_workers() if workers is None else workers
    sw = build_sweep(n_accesses, link_bw_frac)
    res = run_sweep(sw, workers=workers, engine=engine)
    per_call = res.us_per_call  # per-cell sim cost, worker-count independent
    grid = res.grid("workload", "scheme")
    rows = []
    for w in WORKLOADS:
        base = grid[(w, "local")].metrics.cycles
        for s in SCHEMES:
            slow = grid[(w, s)].metrics.cycles / base
            rows.append((f"fig2/{w}/{s}", per_call, f"slowdown={slow:.3f}"))
    g = scheme_geomean(res.rows)
    rows.append(("fig2/geomean_daemon_vs_page", per_call, f"speedup={g:.3f}"))
    write_bench(bench_path, res, derived={
        "daemon_vs_page_geomean": g,
        "link_bw_frac": link_bw_frac,
        "normalization": "cycles / cycles(local) per workload",
    })
    return rows


def compare(n_accesses: int = 20_000, link_bw_frac: float = 0.25,
            workers: int | None = None, engine: str = "python") -> dict:
    """Serial vs parallel on the same grid: identical Metrics, wall speedup."""
    workers = default_workers() if workers is None else workers
    sw = build_sweep(n_accesses, link_bw_frac)
    serial = run_sweep(sw, workers=1, engine=engine)
    par = run_sweep(sw, workers=workers, engine=engine)
    identical = all(
        a.metrics.as_dict() == b.metrics.as_dict()
        for a, b in zip(serial.rows, par.rows)
    )
    return {
        "cells": len(sw),
        "workers": par.workers,
        "serial_s": serial.wall_s,
        "parallel_s": par.wall_s,
        "speedup": serial.wall_s / max(par.wall_s, 1e-9),
        "identical": identical,
    }


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--compare", action="store_true",
                    help="serial-vs-parallel parity + speedup check")
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-accesses", type=int, default=20_000)
    ap.add_argument("--link-bw-frac", type=float, default=0.25)
    args = ap.parse_args()
    if args.compare:
        r = compare(args.n_accesses, args.link_bw_frac, args.workers)
        print(f"cells={r['cells']} workers={r['workers']} "
              f"serial={r['serial_s']:.2f}s parallel={r['parallel_s']:.2f}s "
              f"speedup={r['speedup']:.2f}x identical={r['identical']}")
        if not r["identical"]:
            raise SystemExit("parallel sweep diverged from serial sweep")
        return
    for tag, us, derived in run(args.n_accesses, args.link_bw_frac, args.workers):
        print(f"{tag},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
