"""Paper Fig. 2: data-movement overheads of each scheme, normalized to the
monolithic `local` configuration, per workload."""
from __future__ import annotations

import time

from repro.core.sim import SCHEMES, SimConfig, fig2, slowdowns

WORKLOADS = ("pr", "bf", "ts", "nw", "dr", "pf", "st", "ml")


def run(n_accesses: int = 20_000, link_bw_frac: float = 0.25):
    cfg = SimConfig(link_bw_frac=link_bw_frac)
    rows = []
    t0 = time.time()
    grid = fig2(cfg, workloads=WORKLOADS, schemes=SCHEMES, n_accesses=n_accesses)
    per_call = (time.time() - t0) * 1e6 / (len(WORKLOADS) * len(SCHEMES))
    slow = slowdowns(grid)
    for w in WORKLOADS:
        for s in SCHEMES:
            rows.append((f"fig2/{w}/{s}", per_call, f"slowdown={slow[w][s]:.3f}"))
    dae = [slow[w]["daemon"] for w in WORKLOADS]
    page = [slow[w]["page"] for w in WORKLOADS]
    import math

    g = math.exp(sum(math.log(p / d) for p, d in zip(page, dae)) / len(dae))
    rows.append((f"fig2/geomean_daemon_vs_page", per_call, f"speedup={g:.3f}"))
    return rows
