"""Offline threshold fit for the 'tuned' movement controller (DESIGN.md
§2.12).

Sweeps candidate ``(page_fast, throttle_hi)`` pairs per workload on the
batch engine — daemon cycles at the congested end of the paper's network
range (link_bw_frac=0.125) — and prints the per-workload argmin as the
``TUNED_THRESHOLDS`` literal for src/repro/core/sim/controller.py.  The
candidate grid includes the fixed constants, so a fitted entry is never
worse than ``fixed`` at the fit size by construction.

The fit is intentionally in-process (``run_batch`` directly, no worker
pool): candidates are applied by patching ``TUNED_THRESHOLDS`` before the
batch frames instantiate their controllers, which only works when frame
construction shares the patching interpreter.

Usage::

    PYTHONPATH=src python benchmarks/fit_controller.py [--n-accesses N]

then paste the printed dict over ``TUNED_THRESHOLDS`` and re-run
``benchmarks/run.py --quick --engine batch --only fig11`` to refresh the
gated ledger keys.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import SimConfig
from repro.core.sim import controller as ctrl_mod
from repro.core.sim.engine_batch import BatchCell, TracePool, run_batch
from repro.core.sim.runner import (
    ABLATION_WORKLOADS,
    KERNEL_WORKLOADS,
    UPLINK_WORKLOADS,
)

# the candidate grid: page_fast (race/compress trigger) x throttle_hi
# (page-issue backpressure), fixed constants (0.3, 0.75) included
PAGE_FAST_GRID = (0.1, 0.2, 0.3, 0.4, 0.5)
THROTTLE_HI_GRID = (0.5, 0.65, 0.75, 0.9)
FIT_BW_FRAC = 0.125


def fit(n_accesses: int = 8_000, n_kernel_accesses: int | None = None,
        verbose: bool = True) -> dict:
    if n_kernel_accesses is None:
        n_kernel_accesses = 2 * n_accesses
    cfg = SimConfig(link_bw_frac=FIT_BW_FRAC, controller="tuned")
    workloads = tuple(dict.fromkeys(
        tuple(ABLATION_WORKLOADS) + tuple(UPLINK_WORKLOADS)
        + tuple(KERNEL_WORKLOADS)))
    n_of = {w: (n_kernel_accesses if w in KERNEL_WORKLOADS else n_accesses)
            for w in workloads}
    tp = TracePool()  # share trace derivation across all candidates
    best: dict = {}
    saved = dict(ctrl_mod.TUNED_THRESHOLDS)
    try:
        for pf in PAGE_FAST_GRID:
            for th in THROTTLE_HI_GRID:
                ctrl_mod.TUNED_THRESHOLDS.clear()
                ctrl_mod.TUNED_THRESHOLDS.update(
                    {w: (pf, th) for w in workloads})
                cells = [BatchCell(w, "daemon", cfg, seed=0,
                                   n_accesses=n_of[w]) for w in workloads]
                res = run_batch(cells, trace_pool=tp)
                for w, m in zip(workloads, res.metrics):
                    cur = best.get(w)
                    if cur is None or m.cycles < cur[0]:
                        best[w] = (m.cycles, pf, th)
                if verbose:
                    print(f"# candidate ({pf:.2f}, {th:.2f}) done",
                          file=sys.stderr)
    finally:
        ctrl_mod.TUNED_THRESHOLDS.clear()
        ctrl_mod.TUNED_THRESHOLDS.update(saved)
    return {w: (pf, th) for w, (_, pf, th) in best.items()}


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-accesses", type=int, default=8_000)
    args = ap.parse_args()
    fitted = fit(args.n_accesses)
    print("TUNED_THRESHOLDS: Dict[str, tuple] = {")
    for w, (pf, th) in fitted.items():
        print(f'    "{w}": ({pf:.2f}, {th:.2f}),')
    print("}")


if __name__ == "__main__":
    main()
