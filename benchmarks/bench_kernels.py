"""Kernel micro-benchmarks: wall time of the jnp reference paths on CPU
(the Pallas kernels are TPU-targeted; interpret mode measures Python, not
hardware) plus the kernels' analytic TPU roofline estimates."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.block_quant import ops as bq
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.ref import selective_scan_ref

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    rows = []
    # block_quant: bytes-bound kernel; TPU est = rw bytes / HBM bw
    x = jax.random.normal(jax.random.key(0), (4096, 4096), jnp.float32)
    us = _time(lambda a: bq.quantize(a), x)
    bytes_rw = x.size * 4 + x.size + 4 * (x.size // 128)
    tpu_us = bytes_rw / HBM_BW * 1e6
    rows.append(("kernels/block_quant_16M", us, f"tpu_roofline_us={tpu_us:.1f}"))

    q = jax.random.normal(jax.random.key(1), (1, 1024, 8, 128), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(2), (1, 1024, 2, 128), jnp.bfloat16)
    us = _time(lambda a, b: attention_ref(a, b, b), q, k)
    flops = 4 * 1024 * 1024 * 8 * 128  # 2 matmuls
    rows.append(
        ("kernels/flash_attention_1k", us, f"tpu_roofline_us={flops/PEAK_FLOPS*1e6:.1f}")
    )

    b, s, d, n = 1, 512, 512, 16
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(3), (b, s, d)))
    a = -jnp.exp(jax.random.normal(jax.random.key(4), (d, n)) * 0.5)
    bm = jax.random.normal(jax.random.key(5), (b, s, n))
    cm = jax.random.normal(jax.random.key(6), (b, s, n))
    xx = jax.random.normal(jax.random.key(7), (b, s, d))
    us = _time(lambda *t: selective_scan_ref(*t)[0], dt, a, bm, cm, xx)
    flops = 6 * b * s * d * n
    rows.append(
        ("kernels/mamba_scan_512", us, f"tpu_roofline_us={flops/PEAK_FLOPS*1e6:.2f}")
    )
    return rows
