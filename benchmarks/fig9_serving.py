"""Request-level serving grid (fig9, DESIGN.md §2.9): tail latency and
goodput under open-loop load on a 4-CC disaggregated node.

Two tenant profiles run the same offered-load x router x scheme grid over
the request scheduling layer (serving.py):

  llm   — prefill = one fa_prefill burst, decode = fa_decode slices (the
          captured Pallas streams of DESIGN.md §2.8)
  graph — a graph-analytics tenant issuing query requests ('pr' phases)

Each tenant merges into BENCH_sim.json as ``fig9_serving_<tenant>`` with
gated derived keys ``daemon_vs_page_p99@load=<L>:tenant=<T>`` (geomean
over routers of page_p99/daemon_p99; >1 = daemon serves the tail better).

The headline mirrors fig8's at the request level: the page-dense LLM
kernel streams keep page granularity near-optimal (ratios ~1x), while the
sparse graph tenant's p99 collapses under page-granularity movement —
daemon wins the tail by an order of magnitude.  That pair is the
request-level restatement of the paper's robustness claim "across
application characteristics".
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import (
    default_workers,
    fig9_serving_spec,
    fig9_tails,
    run_sweep,
    write_bench,
)

from benchmarks import BENCH_PATH

TENANTS = ("llm", "graph")


def run(n_requests: int = 96, prefill_accesses: int = 1024,
        decode_steps: int = 4, decode_accesses: int = 256,
        workers: int | None = None, engine: str = "python",
        bench_path: str = BENCH_PATH):
    workers = default_workers() if workers is None else workers
    rows = []
    for tenant in TENANTS:
        sw = fig9_serving_spec(
            tenant=tenant, n_requests=n_requests,
            prefill_accesses=prefill_accesses, decode_steps=decode_steps,
            decode_accesses=decode_accesses)
        res = run_sweep(sw, workers=workers, engine=engine)
        per_call = res.us_per_call
        t_rows, derived = fig9_tails(res, tenant)
        write_bench(bench_path, res, derived=derived)
        for r in t_rows:
            if r["router"] == "geomean":
                rows.append(
                    (f"fig9/{tenant}/load{r['offered_load']:g}/geomean",
                     per_call, f"p99_ratio={r['p99_ratio']:.3f}"))
            else:
                rows.append(
                    (f"fig9/{tenant}/load{r['offered_load']:g}/{r['router']}",
                     per_call,
                     f"p99_ratio={r['p99_ratio']:.3f};"
                     f"daemon_p99={r['daemon_p99']:.0f};"
                     f"daemon_goodput={r['daemon_goodput']:.2f}"))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-requests", type=int, default=96)
    ap.add_argument("--prefill-accesses", type=int, default=1024)
    ap.add_argument("--decode-steps", type=int, default=4)
    ap.add_argument("--decode-accesses", type=int, default=256)
    args = ap.parse_args()
    for tag, us, derived in run(args.n_requests, args.prefill_accesses,
                                args.decode_steps, args.decode_accesses,
                                args.workers):
        print(f"{tag},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
