"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json (produced by ``python -m repro.launch.dryrun
--all``) and emits one row per (arch x cell x mesh x movement): the three
terms in seconds, the bottleneck, and MODEL_FLOPS/HLO_FLOPs."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ART = os.environ.get("REPRO_DRYRUN_DIR", "artifacts/dryrun")


def load() -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = json.load(open(f))
        if r.get("ok"):
            recs.append(r)
    return recs


def run():
    rows = []
    for r in load():
        tag = f"roofline/{r['arch']}/{r['cell']}/{r['mesh']}/{r['movement']}"
        ratio = r.get("model_flops_ratio", 0.0)
        derived = (
            f"t_comp={r['t_compute_s']:.4f};t_mem={r['t_memory_s']:.4f};"
            f"t_coll={r['t_collective_s']:.4f};bound={r['bottleneck']};"
            f"useful_flops_frac={ratio:.3f}"
        )
        rows.append((tag, r.get("compile_s", 0.0) * 1e6, derived))
    if not rows:
        rows.append(("roofline/missing_artifacts_run_dryrun_all", 0.0, "n/a"))
    return rows
