"""Uplink contention (DESIGN.md §2.7): daemon vs the page scheme as the
CC->MC uplink tightens relative to the downlink.

With ``SimConfig.uplink_bw`` set, line/page request packets and dirty-page
writebacks queue on a per-MC contended uplink instead of being folded into
``net_lat`` / injected into the downlink.  Baselines run a FIFO uplink —
their request packets suffer head-of-line blocking behind 4 KiB writebacks
— while daemon's dual-queue uplink keeps request packets on a protected
class (``1 - writeback_share`` of the bandwidth) and compresses writebacks
off the uplink backlog.

One declarative Sweep over write-heavy workload x uplink_bw x n_ccs x
scheme; the per-uplink_bw daemon-vs-page geomeans merge into BENCH_sim.json
(docs/SWEEPS.md) and are gated in CI by check_bench.py.  The headline:
the geomean *increases* as ``uplink_bw`` drops from 1.0x to 0.25x of
``link_bw`` — bandwidth asymmetry makes the reverse path first-order.

:func:`run_wshare` (run.py section ``fig7_wshare``) additionally surfaces
``writeback_share`` as a swept axis at a fixed 0.125x uplink.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import (
    SimConfig,
    Sweep,
    default_workers,
    fig7_uplink_spec,
    run_sweep,
    scheme_geomean,
    scheme_ratio,
    write_bench,
)

from benchmarks import BENCH_PATH


def run(n_accesses: int = 15_000, workers: int | None = None,
        engine: str = "python",
        bench_path: str = BENCH_PATH):
    workers = default_workers() if workers is None else workers
    sw = fig7_uplink_spec(n_accesses=n_accesses)
    res = run_sweep(sw, workers=workers, engine=engine)
    per_call = res.us_per_call  # per-cell sim cost, worker-count independent
    rows, derived = [], {}
    for ub in sw.axes["uplink_bw"]:
        sub = res.filter(uplink_bw=ub)
        g = scheme_geomean(sub)
        derived[f"daemon_vs_page_geomean@uplink_bw={ub}"] = g
        rows.append((f"fig7/uplink_bw{ub}/geomean_daemon_vs_page", per_call,
                     f"speedup={g:.3f}"))
        for key, ratio in sorted(scheme_ratio(sub).items()):
            k = dict(key)
            rows.append((f"fig7/{k['workload']}/uplink_bw{ub}/"
                         f"n_ccs{k['n_ccs']}", per_call,
                         f"speedup={ratio:.3f}"))
    write_bench(bench_path, res, derived=derived)
    return rows


def run_wshare(n_accesses: int = 15_000, workers: int | None = None,
               engine: str = "python",
               bench_path: str = BENCH_PATH):
    """ROADMAP uplink follow-on: ``writeback_share`` as a swept axis.  At a
    strongly-asymmetric (0.125x) uplink, sweep the bandwidth fraction
    daemon's dual-queue uplink grants the writeback (bulk) class when both
    classes are backlogged; request packets keep ``1 - writeback_share``.
    The page scheme's FIFO uplink ignores the knob, so the daemon-vs-page
    geomean per share value isolates how much request-packet protection is
    worth — it shrinks as ``writeback_share`` grows and daemon's own
    requests lose their protected lane (the share only binds when both
    classes are simultaneously backlogged, so the spread is percent-level,
    not the head-of-line cliff of the fifo-vs-dual comparison in fig7).
    Derived ``daemon_vs_page_geomean@writeback_share=<s>`` keys are
    CI-gated like every other geomean."""
    workers = default_workers() if workers is None else workers
    base = SimConfig()
    sw = Sweep(
        name="fig7_wshare",
        axes={
            "workload": ("wh", "st", "pf"),
            "writeback_share": (0.1, 0.4, 0.8),
            "scheme": ("page", "daemon"),
        },
        base=base.with_(uplink_bw=0.125 * base.link_bw),
        n_accesses=n_accesses,
    )
    res = run_sweep(sw, workers=workers, engine=engine)
    per_call = res.us_per_call
    rows, derived = [], {}
    for ws in sw.axes["writeback_share"]:
        sub = res.filter(writeback_share=ws)
        g = scheme_geomean(sub)
        derived[f"daemon_vs_page_geomean@writeback_share={ws}"] = g
        rows.append((f"fig7_wshare/ws{ws}/geomean_daemon_vs_page", per_call,
                     f"speedup={g:.3f}"))
        for key, ratio in sorted(scheme_ratio(sub).items()):
            k = dict(key)
            rows.append((f"fig7_wshare/{k['workload']}/ws{ws}", per_call,
                         f"speedup={ratio:.3f}"))
    write_bench(bench_path, res, derived=derived)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-accesses", type=int, default=15_000)
    ap.add_argument("--wshare", action="store_true",
                    help="run the writeback_share sweep instead of the "
                         "uplink_bw grid")
    args = ap.parse_args()
    fn = run_wshare if args.wshare else run
    for tag, us, derived in fn(args.n_accesses, args.workers):
        print(f"{tag},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
