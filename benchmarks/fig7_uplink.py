"""Uplink contention (DESIGN.md §2.7): daemon vs the page scheme as the
CC->MC uplink tightens relative to the downlink.

With ``SimConfig.uplink_bw`` set, line/page request packets and dirty-page
writebacks queue on a per-MC contended uplink instead of being folded into
``net_lat`` / injected into the downlink.  Baselines run a FIFO uplink —
their request packets suffer head-of-line blocking behind 4 KiB writebacks
— while daemon's dual-queue uplink keeps request packets on a protected
class (``1 - writeback_share`` of the bandwidth) and compresses writebacks
off the uplink backlog.

One declarative Sweep over write-heavy workload x uplink_bw x n_ccs x
scheme; the per-uplink_bw daemon-vs-page geomeans merge into BENCH_sim.json
(docs/SWEEPS.md) and are gated in CI by check_bench.py.  The headline:
the geomean *increases* as ``uplink_bw`` drops from 1.0x to 0.25x of
``link_bw`` — bandwidth asymmetry makes the reverse path first-order.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import (
    default_workers,
    fig7_uplink_spec,
    run_sweep,
    scheme_geomean,
    scheme_ratio,
    write_bench,
)

from benchmarks import BENCH_PATH


def run(n_accesses: int = 15_000, workers: int | None = None,
        bench_path: str = BENCH_PATH):
    workers = default_workers() if workers is None else workers
    sw = fig7_uplink_spec(n_accesses=n_accesses)
    res = run_sweep(sw, workers=workers)
    per_call = res.us_per_call  # per-cell sim cost, worker-count independent
    rows, derived = [], {}
    for ub in sw.axes["uplink_bw"]:
        sub = res.filter(uplink_bw=ub)
        g = scheme_geomean(sub)
        derived[f"daemon_vs_page_geomean@uplink_bw={ub}"] = g
        rows.append((f"fig7/uplink_bw{ub}/geomean_daemon_vs_page", per_call,
                     f"speedup={g:.3f}"))
        for key, ratio in sorted(scheme_ratio(sub).items()):
            k = dict(key)
            rows.append((f"fig7/{k['workload']}/uplink_bw{ub}/"
                         f"n_ccs{k['n_ccs']}", per_call,
                         f"speedup={ratio:.3f}"))
    write_bench(bench_path, res, derived=derived)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-accesses", type=int, default=15_000)
    args = ap.parse_args()
    for tag, us, derived in run(args.n_accesses, args.workers):
        print(f"{tag},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
