"""Fabric topologies (DESIGN.md §2.11): daemon vs the page scheme across
routed fabrics between the compute and memory pools.

With ``SimConfig.topology`` set, every CC<->MC transfer resolves to an
explicit multi-hop path through a registered fabric (fabric.py) —
store-and-forward at each switch hop, per-port fluid arbitration across all
flows sharing a port.  Daemon's dual-queue line/page partitioning rides
every hop end-to-end, while the baselines' transfers cross FIFO switch
ports where 4 KiB pages head-of-line-block demand lines from *other* CCs
too.

Two declarative Sweeps merge into BENCH_sim.json (docs/SWEEPS.md), gated
in CI by check_bench.py:

  fig10_topology — topology (direct / single_switch / two_tier) x
      workload x n_ccs x scheme.  'direct' is the legacy flat per-MC link
      bundle expressed as a 1-hop fabric; its geomean matches fig5's
      operating point.
  fig10_oversub — the two_tier fabric's leaf/spine trunks tightened from
      non-blocking (oversub=1) to 4:1.  The headline acceptance trend: the
      daemon-vs-page geomean grows monotonically with oversubscription —
      the fabric-level restatement of the paper's Fig. 4 bandwidth sweep.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import (
    default_workers,
    fig10_oversub_spec,
    fig10_topology_spec,
    run_sweep,
    scheme_geomean,
    scheme_ratio,
    write_bench,
)

from benchmarks import BENCH_PATH


def run(n_accesses: int = 15_000, workers: int | None = None,
        engine: str = "python",
        bench_path: str = BENCH_PATH):
    workers = default_workers() if workers is None else workers
    rows = []

    # topology grid: fabric shape x workload x n_ccs, page vs daemon
    sw = fig10_topology_spec(n_accesses=n_accesses)
    res = run_sweep(sw, workers=workers, engine=engine)
    per_call = res.us_per_call  # per-cell sim cost, worker-count independent
    derived = {}
    for topo in sw.axes["topology"]:
        sub = res.filter(topology=topo)
        g = scheme_geomean(sub)
        derived[f"daemon_vs_page_geomean@topo={topo}"] = g
        rows.append((f"fig10/topo_{topo}/geomean_daemon_vs_page", per_call,
                     f"speedup={g:.3f}"))
        for key, ratio in sorted(scheme_ratio(sub).items()):
            k = dict(key)
            rows.append((f"fig10/{k['workload']}/topo_{topo}/"
                         f"n_ccs{k['n_ccs']}", per_call,
                         f"speedup={ratio:.3f}"))
    write_bench(bench_path, res, derived=derived)

    # oversubscription grid: two_tier trunks tightened from 1:1 to 4:1
    so = fig10_oversub_spec(n_accesses=n_accesses)
    reso = run_sweep(so, workers=workers, engine=engine)
    per_call_o = reso.us_per_call
    derived_o = {}
    for o in so.axes["oversub"]:
        sub = reso.filter(oversub=o)
        g = scheme_geomean(sub)
        derived_o[f"daemon_vs_page_geomean@topo=two_tier:oversub={o:g}"] = g
        rows.append((f"fig10/oversub{o:g}/geomean_daemon_vs_page", per_call_o,
                     f"speedup={g:.3f}"))
        for key, ratio in sorted(scheme_ratio(sub).items()):
            k = dict(key)
            rows.append((f"fig10/{k['workload']}/oversub{o:g}/"
                         f"n_ccs{k['n_ccs']}", per_call_o,
                         f"speedup={ratio:.3f}"))
    write_bench(bench_path, reso, derived=derived_o)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-accesses", type=int, default=15_000)
    args = ap.parse_args()
    for tag, us, derived in run(args.n_accesses, args.workers):
        print(f"{tag},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
