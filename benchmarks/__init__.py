"""Benchmark scripts for the paper figures and scenario sweeps.

Simulator sections declare Sweeps (docs/SWEEPS.md) and merge their grids
into the BENCH_sim.json ledger at the repo root.
"""
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(REPO_ROOT, "BENCH_sim.json")
