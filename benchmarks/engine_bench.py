"""Engine parity + throughput benchmark: batch core vs Python oracle.

Runs the canonical fig2 grid twice — once per engine, both serial so the
comparison is per-process apples-to-apples — asserts the batch engine is
cell-for-cell bit-identical to the oracle, and records the measured
speedup in the ledger under the non-gated ``wall_*`` keys (the
``engine_bench`` section).  CI runs this in quick mode; nightly at full
size, so engine-throughput regressions show up in the trend artifact.
"""
from __future__ import annotations

import dataclasses
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import run_sweep, write_bench

from benchmarks import BENCH_PATH
from benchmarks.fig2_schemes import build_sweep


def run(n_accesses: int = 20_000, workers: int | None = None,
        bench_path: str = BENCH_PATH):
    # renamed so the ledger entry does not clobber the fig2 section
    sw = dataclasses.replace(build_sweep(n_accesses), name="engine_bench")
    oracle = run_sweep(sw, workers=1, engine="python")
    batch = run_sweep(sw, workers=1, engine="batch")
    mismatches = [
        a.axes for a, b in zip(oracle.rows, batch.rows)
        if a.metrics.as_dict() != b.metrics.as_dict() or a.seed != b.seed
    ]
    if mismatches:
        raise AssertionError(
            f"batch engine diverged from the oracle on {len(mismatches)} "
            f"cell(s), first: {mismatches[0]!r}")
    speedup = oracle.wall_s / max(batch.wall_s, 1e-9)
    per_call = batch.us_per_call
    write_bench(bench_path, batch, derived={
        "wall_python_s": round(oracle.wall_s, 4),
        "wall_batch_s": round(batch.wall_s, 4),
        "wall_speedup_vs_python": round(speedup, 4),
        "parity_cells": len(batch.rows),
    })
    return [
        ("engine_bench/parity", per_call,
         f"identical=True;cells={len(batch.rows)}"),
        ("engine_bench/speedup", per_call,
         f"speedup={speedup:.2f}x;python_s={oracle.wall_s:.2f};"
         f"batch_s={batch.wall_s:.2f}"),
    ]


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-accesses", type=int, default=20_000)
    args = ap.parse_args()
    for tag, us, derived in run(n_accesses=args.n_accesses):
        print(f"{tag},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
