"""Paper ablation study (fig6): each of DaeMon's techniques contributes,
the synergy dominates.

One declarative Sweep over policy x workload at the congested end of the
network range (link_bw_frac=0.125).  The ablation policies strip the full
daemon composition down technique by technique (policy.py / DESIGN.md
§2.6) — three remove exactly one technique, both_dualq keeps only the
first two:

  both_dualq        — decoupled movement + partitioning only (no selection
                      unit, no throttle, no compression)
  daemon_fifo       — daemon minus bandwidth partitioning
  daemon_fixed_gran — daemon minus adaptive granularity selection
  daemon_nocomp     — daemon minus link compression

The per-policy geomean speedups over 'page' merge into BENCH_sim.json
(docs/SWEEPS.md) under ``policy_vs_page_geomean@<policy>`` and are gated in
CI by check_bench.py.  The paper's synergy claim shows up as every ablation
landing strictly between 'page' (1.0) and 'daemon' on the geomean.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import (
    default_workers,
    fig6_ablation_spec,
    fig6_geomeans,
    run_sweep,
    write_bench,
)

from benchmarks import BENCH_PATH


def run(n_accesses: int = 20_000, workers: int | None = None,
        bench_path: str = BENCH_PATH):
    workers = default_workers() if workers is None else workers
    sw = fig6_ablation_spec(n_accesses=n_accesses)
    res = run_sweep(sw, workers=workers)
    per_call = res.us_per_call  # per-cell sim cost, worker-count independent
    rows, derived = [], {}
    for row in fig6_geomeans(res):  # the same numbers runner.fig6_ablation returns
        p, gm = row["policy"], row["geomean_vs_page"]
        derived[f"policy_vs_page_geomean@{p}"] = gm
        rows.append((f"fig6/{p}/geomean_vs_page", per_call, f"speedup={gm:.3f}"))
        for w, r in row["per_workload"].items():
            rows.append((f"fig6/{p}/{w}", per_call, f"speedup={r:.3f}"))
    write_bench(bench_path, res, derived=derived)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-accesses", type=int, default=20_000)
    args = ap.parse_args()
    for tag, us, derived in run(args.n_accesses, args.workers):
        print(f"{tag},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
