"""Paper ablation study (fig6): each of DaeMon's techniques contributes,
the synergy dominates.

One declarative Sweep over policy x workload at the congested end of the
network range (link_bw_frac=0.125).  The ablation policies strip the full
daemon composition down technique by technique (policy.py / DESIGN.md
§2.6) — three remove exactly one technique, both_dualq keeps only the
first two:

  both_dualq        — decoupled movement + partitioning only (no selection
                      unit, no throttle, no compression)
  daemon_fifo       — daemon minus bandwidth partitioning
  daemon_fixed_gran — daemon minus adaptive granularity selection
  daemon_nocomp     — daemon minus link compression

The per-policy geomean speedups over 'page' merge into BENCH_sim.json
(docs/SWEEPS.md) under ``policy_vs_page_geomean@<policy>`` and are gated in
CI by check_bench.py.  The paper's synergy claim shows up as every ablation
landing strictly between 'page' (1.0) and 'daemon' on the geomean.

:func:`run_variance` (run.py section ``fig6_var``, nightly-only) re-runs
the grid with a seed axis + ``derive_seeds=True`` and reports each
geomean as mean ± 95% CI across seeds.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import (
    default_workers,
    fig6_ablation_spec,
    fig6_geomeans,
    geomean,
    run_sweep,
    write_bench,
)

from benchmarks import BENCH_PATH


def run(n_accesses: int = 20_000, workers: int | None = None,
        engine: str = "python",
        bench_path: str = BENCH_PATH):
    workers = default_workers() if workers is None else workers
    sw = fig6_ablation_spec(n_accesses=n_accesses)
    res = run_sweep(sw, workers=workers, engine=engine)
    per_call = res.us_per_call  # per-cell sim cost, worker-count independent
    rows, derived = [], {}
    for row in fig6_geomeans(res):  # the same numbers runner.fig6_ablation returns
        p, gm = row["policy"], row["geomean_vs_page"]
        derived[f"policy_vs_page_geomean@{p}"] = gm
        rows.append((f"fig6/{p}/geomean_vs_page", per_call, f"speedup={gm:.3f}"))
        for w, r in row["per_workload"].items():
            rows.append((f"fig6/{p}/{w}", per_call, f"speedup={r:.3f}"))
    write_bench(bench_path, res, derived=derived)
    return rows


# two-sided 97.5% Student-t critical values by degrees of freedom (k-1
# seeds); untabulated df fall back to the nearest LOWER entry (a larger,
# conservative critical value); beyond df=30 the normal 1.96 is close enough
_T975 = {1: 12.706, 2: 4.303, 3: 3.182, 4: 2.776, 5: 2.571, 6: 2.447,
         7: 2.365, 8: 2.306, 9: 2.262, 10: 2.228, 15: 2.131, 20: 2.086,
         30: 2.042}


def _t975(df: int) -> float:
    if df > max(_T975):
        return 1.96
    return _T975.get(df) or _T975[max(d for d in _T975 if d <= df)]


def run_variance(n_accesses: int = 20_000, workers: int | None = None,
                 seeds=(0, 1, 2, 3, 4), engine: str = "python",
                 bench_path: str = BENCH_PATH):
    """Variance study on the ablation grid (ROADMAP item, nightly-only):
    the fig6 grid re-run with a ``seed`` axis and ``derive_seeds=True`` so
    every seed draws decorrelated traces while schemes within a seed stay
    trace-paired (the derived seed excludes the scheme axis — sweep.py),
    keeping each per-seed ratio a paired comparison.  Reports each
    ablation's geomean speedup over 'page' as mean ± a 95% CI across seeds
    (Student-t critical value — at 5 seeds the normal 1.96 would
    under-cover).  Ledger keys use the non-gated ``ablation_geomean_*``
    prefix — the quick CI grid and its gated single-seed fig6 keys are
    unchanged."""
    workers = default_workers() if workers is None else workers
    import dataclasses

    base = fig6_ablation_spec(n_accesses=n_accesses)
    sw = dataclasses.replace(
        base, name="fig6_variance",
        axes={**dict(base.axes), "seed": tuple(seeds)},
        derive_seeds=True,
    )
    res = run_sweep(sw, workers=workers, engine=engine)
    per_call = res.us_per_call
    rows, derived = [], {}
    g = res.grid("workload", "scheme", "seed")
    for p in sw.axes["scheme"]:
        if p == "page":
            continue
        per_seed = []
        for seed in sw.axes["seed"]:
            per_seed.append(geomean([
                g[(w, "page", seed)].metrics.cycles
                / g[(w, p, seed)].metrics.cycles
                for w in sw.axes["workload"]
            ]))
        k = len(per_seed)
        mean = sum(per_seed) / k
        var = sum((x - mean) ** 2 for x in per_seed) / max(1, k - 1)
        ci = _t975(k - 1) * (var ** 0.5) / (k ** 0.5)
        derived[f"ablation_geomean_mean@{p}"] = mean
        derived[f"ablation_geomean_ci95@{p}"] = ci
        rows.append((f"fig6var/{p}/geomean_vs_page", per_call,
                     f"mean={mean:.3f};ci95={ci:.3f};seeds={k}"))
    write_bench(bench_path, res, derived=derived)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-accesses", type=int, default=20_000)
    ap.add_argument("--variance", action="store_true",
                    help="run the seed-axis variance grid instead of the "
                         "single-seed ablation grid")
    args = ap.parse_args()
    fn = run_variance if args.variance else run
    for tag, us, derived in fn(args.n_accesses, args.workers):
        print(f"{tag},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
