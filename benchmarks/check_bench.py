"""Benchmark-regression gate for the BENCH_sim.json ledger (docs/SWEEPS.md).

Compares the derived daemon-vs-page geomeans of a freshly produced ledger
against the committed baseline, section by section, with a relative
tolerance (default 5%).  The committed BENCH_sim.json is the output of the
exact CI command::

    PYTHONPATH=src python benchmarks/run.py --quick --engine batch \
        --only fig2,fig4_top,fig4_bottom,sweep_jitter,sweep_nmcs,fig5,fig6,fig7,fig7_wshare,fig8,fig9,fig10,fig11,fig12,engine_bench

so CI can regenerate it deterministically and fail the workflow when a
code change moves any geomean by more than the tolerance — in EITHER
direction: a >5% improvement means the committed ledger is stale and must
be regenerated alongside the change.  Gated keys are the derived
``daemon_vs_page_geomean*`` entries — including the fig10 fabric keys
``daemon_vs_page_geomean@topo=<t>`` and
``...@topo=two_tier:oversub=<o>`` and the fig11 movement-controller keys
``daemon_vs_page_geomean@ctrl=<c>`` / ``...@ctrl=<c>:grid=uplink`` /
``...@ctrl=<c>:kernel=<w>`` and the fig12 memory-pool keys
``daemon_vs_page_geomean@mem={inf|<capacity>}:place=<placement>``
(DESIGN.md §2.13), matched by the same prefix — the fig6
ablation ``policy_vs_page_geomean@<policy>`` entries, and the fig9
serving tail ratios ``daemon_vs_page_p99@load=<L>:tenant=<T>``.  The ``wall_*``
throughput keys (and the ``engine``/``workers``/``wall_s`` entry fields)
are observability-only and never gated; ``--trend`` extracts them into
the nightly throughput-trend CSV.

Comparisons are refused (exit 1) when a section's sweep spec — axes,
n_accesses, footprint, seeding, base SimConfig — differs between baseline
and fresh: the numbers would not be commensurable.

Usage (CI copies the committed ledger aside before re-running benchmarks)::

    cp BENCH_sim.json /tmp/BENCH_baseline.json
    PYTHONPATH=src python benchmarks/run.py --quick --only ...
    PYTHONPATH=src python benchmarks/check_bench.py \
        --baseline /tmp/BENCH_baseline.json --fresh BENCH_sim.json
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import sys

GATED_PREFIXES = ("daemon_vs_page_geomean", "policy_vs_page_geomean",
                  "daemon_vs_page_p99")

# observability-only derived keys (wall-clock, throughput): recorded in every
# ledger entry, charted by the nightly trend artifact, never gated
WALL_PREFIX = "wall_"


def _gated(key: str) -> bool:
    return key.startswith(GATED_PREFIXES)


def write_trend(sweeps: dict, path: str) -> int:
    """Extract the non-gated ``wall_*`` throughput keys into a flat CSV
    (section, engine, workers, n_cells, wall_s, cells_per_s, cpu_s_per_cell)
    — the nightly throughput-trend artifact.  Returns the row count."""
    rows = []
    for name in sorted(sweeps):
        entry = sweeps[name]
        d = entry.get("derived", {})
        rows.append({
            "section": name,
            "engine": entry.get("engine", "python"),
            "workers": entry.get("workers", 1),
            "n_cells": entry.get("n_cells", len(entry.get("rows", []))),
            "wall_s": d.get("wall_s", entry.get("wall_s", "")),
            "cells_per_s": d.get("wall_cells_per_s", ""),
            "cpu_s_per_cell": d.get("wall_cpu_s_per_cell", ""),
        })
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]) if rows else
                           ["section"])
        w.writeheader()
        w.writerows(rows)
    return len(rows)


def write_step_summary(rows: list) -> None:
    """Render the gate comparison as a markdown table into
    ``$GITHUB_STEP_SUMMARY`` (no-op outside Actions) so geomean drift is
    readable from the run page without downloading artifacts."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = ["## Benchmark-regression gate", "",
             "| section / key | baseline | fresh | rel | status |",
             "|---|---:|---:|---:|---|"]
    for name, key, base, new, rel, status in rows:
        mark = "✅" if status == "ok" else "❌"
        if base is None or new is None:
            lines.append(f"| {name}/{key or '<section>'} | — | — | — | "
                         f"{mark} {status} |")
        else:
            lines.append(f"| {name}/{key} | {base:.4f} | {new:.4f} | "
                         f"{rel:+.2%} | {mark} {status} |")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def load_sweeps(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "sweeps" not in doc:
        sys.exit(f"{path}: not a BENCH_sim.json ledger (no 'sweeps' key)")
    return doc["sweeps"]


def compare(baseline: dict, fresh: dict, tol: float,
            sections: list[str] | None = None):
    """Yield (section, key, base, new, rel, status) rows; status is one of
    'ok', 'regression', 'spec-mismatch', 'missing-section', 'missing-key'."""
    names = sections if sections else sorted(
        n for n in baseline if any(
            _gated(k) for k in baseline[n].get("derived", {})))
    for name in names:
        if name not in baseline:
            yield (name, "", None, None, 0.0, "missing-section")
            continue
        if name not in fresh:
            yield (name, "", None, None, 0.0, "missing-section")
            continue
        b, f = baseline[name], fresh[name]
        for part in ("axes", "spec"):
            if b.get(part) != f.get(part):
                yield (name, part, None, None, 0.0, "spec-mismatch")
                break
        else:
            bd = b.get("derived", {})
            fd = f.get("derived", {})
            for key in sorted(bd):
                if not _gated(key):
                    continue
                if key not in fd:
                    yield (name, key, bd[key], None, 0.0, "missing-key")
                    continue
                base, new = float(bd[key]), float(fd[key])
                if base:
                    rel = (new - base) / abs(base)
                elif new == 0.0:
                    rel = 0.0  # both zero: a match, not a div-by-zero blowup
                else:
                    rel = float("inf")
                yield (name, key, base, new,
                       rel, "ok" if abs(rel) <= tol else "regression")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline",
                    help="committed BENCH_sim.json (copied aside before "
                         "re-running); optional when only --trend is wanted")
    ap.add_argument("--fresh", required=True,
                    help="freshly produced BENCH_sim.json")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="max relative drift per derived geomean (default 5%%)")
    ap.add_argument("--sections", default="",
                    help="comma-separated sweep names to gate "
                         "(default: every baseline section with gated keys)")
    ap.add_argument("--trend", default="",
                    help="also write the wall_* throughput keys of --fresh "
                         "to this CSV (the nightly trend artifact)")
    args = ap.parse_args()
    sections = [s.strip() for s in args.sections.split(",") if s.strip()] or None

    fresh = load_sweeps(args.fresh)
    if args.trend:
        n = write_trend(fresh, args.trend)
        print(f"throughput trend: {n} section(s) -> {args.trend}")
    if not args.baseline:
        if not args.trend:
            ap.error("--baseline is required unless --trend is given")
        return

    baseline = load_sweeps(args.baseline)
    failures = 0
    checked = 0
    rows = list(compare(baseline, fresh, args.tolerance, sections))
    write_step_summary(rows)
    for name, key, base, new, rel, status in rows:
        if status == "ok":
            checked += 1
            print(f"OK    {name}/{key}: {base:.4f} -> {new:.4f} ({rel:+.2%})")
        elif status == "regression":
            checked += 1
            failures += 1
            print(f"FAIL  {name}/{key}: {base:.4f} -> {new:.4f} "
                  f"({rel:+.2%}, beyond {args.tolerance:.0%} tolerance)")
        elif status == "spec-mismatch":
            failures += 1
            print(f"FAIL  {name}: sweep {key} differ between baseline and "
                  f"fresh — results not comparable; regenerate the committed "
                  f"ledger with the CI quick command")
        else:
            failures += 1
            print(f"FAIL  {name}/{key or '<section>'}: {status} "
                  f"(see `PYTHONPATH=src python -m benchmarks.run --list` "
                  f"for the known sections and registered "
                  f"policies/workloads)")
    if checked == 0 and failures == 0:
        sys.exit("no gated derived keys found — nothing was checked")
    if failures:
        sys.exit(f"{failures} benchmark-regression failure(s) "
                 f"(tolerance {args.tolerance:.0%})")
    print(f"benchmark gate passed: {checked} geomean(s) within "
          f"{args.tolerance:.0%}")


if __name__ == "__main__":
    main()
