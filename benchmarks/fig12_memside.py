"""Memory-pool grid (fig12, DESIGN.md §2.13): finite per-MC capacity,
first-class placement policies, and hot-page churn under multi-tenant
'+'-mixes — the scenario family the paper never swept (its evaluation
treats remote memory as an infinite passive address space).

One declarative Sweep: tenant mix x placement (page / first_touch /
capacity_aware) x capacity pressure (infinite / mild / heavy) x scheme,
with four CCs contending for four finite MCs.  The derived daemon-vs-page
geomeans per (capacity, placement) cell merge into BENCH_sim.json under
``daemon_vs_page_geomean@mem={inf|<cap>}:place=<p>`` and are gated in CI
by check_bench.py.

The headline question: do DaeMon's decoupled granularities hold their
advantage when page migration also triggers capacity evictions?  The
``@mem=inf`` rows pin the legacy infinite-pool behaviour (placement still
varies the MC mapping); the ``@mem=128`` rows are eviction-dominated —
every migrated page can push a cold resident out through the contended
uplink.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.sim import (
    default_workers,
    fig12_geomeans,
    fig12_memside_spec,
    run_sweep,
    write_bench,
)

from benchmarks import BENCH_PATH


def run(n_accesses: int = 20_000, workers: int | None = None,
        engine: str = "python",
        bench_path: str = BENCH_PATH):
    workers = default_workers() if workers is None else workers
    sw = fig12_memside_spec(n_accesses=n_accesses)
    res = run_sweep(sw, workers=workers, engine=engine)
    derived = fig12_geomeans(res)
    write_bench(bench_path, res, derived=derived)
    per_call = res.us_per_call
    rows = []
    for k, v in derived.items():
        suffix = k.split("@mem=", 1)[1]
        rows.append((f"fig12/{suffix}", per_call, f"speedup={v:.3f}"))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-accesses", type=int, default=20_000)
    ap.add_argument("--engine", choices=("python", "batch"),
                    default="python")
    args = ap.parse_args()
    for tag, us, derived in run(args.n_accesses, args.workers,
                                engine=args.engine):
        print(f"{tag},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
