"""End-to-end driver: train a ~100M-parameter llama-like LM for a few hundred
steps with checkpointing, WSD schedule, and the DaeMon movement engine.

    PYTHONPATH=src python examples/train_lm.py                 # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --small --steps 30   # CI-sized
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig  # noqa: E402


def lm_100m() -> ModelConfig:
    return ModelConfig(
        name="llama-100m",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab_size=32_000,
        attn_kind="full",
        schedule="wsd",
        attn_chunk=256,
    )


def lm_small() -> ModelConfig:
    return dataclasses.replace(
        lm_100m(), name="llama-8m", num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=2, d_ff=688, vocab_size=4_096,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--movement", default="daemon", choices=["baseline", "daemon"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = lm_small() if args.small else lm_100m()

    # register the custom config then reuse the standard driver
    import repro.configs as C
    from repro.launch import train as T

    C.REGISTRY[cfg.name] = cfg
    from repro.models import model as M

    print(f"training {cfg.name}: {M.param_count(cfg)/1e6:.1f}M params, "
          f"{args.steps} steps, movement={args.movement}")
    _, _, losses = T.train(
        cfg.name, reduced=False, steps=args.steps, global_batch=args.batch,
        seq_len=args.seq, movement=args.movement, ckpt_dir=args.ckpt_dir,
        ckpt_every=100, log_every=10,
    )
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    if args.steps >= 50:  # shorter runs are still inside LR warmup
        assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
