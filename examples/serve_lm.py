"""Serve a small model with batched requests through prefill + decode, with
the DaeMon movement engine on the weight/KV path.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-14b --batch 4
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    from repro.launch.serve import serve

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--movement", default="daemon")
    args = ap.parse_args()

    r = serve(
        args.arch, reduced=True, batch=args.batch, prompt_len=args.prompt_len,
        gen_tokens=args.gen, movement=args.movement,
    )
    print(
        f"arch={args.arch} batch={args.batch}: prefill {r['prefill_s']*1e3:.0f} ms, "
        f"decode {r['decode_s_per_token']*1e3:.1f} ms/token, "
        f"throughput {r['tokens_per_s']:.1f} tok/s"
    )
    print("generated token matrix:", r["tokens"].shape)


if __name__ == "__main__":
    main()
