"""Quickstart: the two faces of this repo in ~60 seconds on CPU.

1. The faithful DaeMon reproduction: simulate the paper's data-movement
   schemes on a disaggregated system and print Fig-2-style slowdowns.
2. The TPU-native integration: train a small LM with the DaeMon movement
   engine (bf16 page-class parameter movement + compressed grad path).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")


def simulate():
    from repro.core.sim import SimConfig, run_one

    print("=== DaeMon DS simulation (paper Fig. 2 slice) ===")
    cfg = SimConfig(link_bw_frac=0.25)
    for w in ("pr", "st"):
        loc = run_one(w, "local", cfg, n_accesses=8000)
        rows = {s: run_one(w, s, cfg, n_accesses=8000) for s in ("page", "cacheline", "daemon")}
        line = " ".join(f"{s}={m.cycles/loc.cycles:6.2f}x" for s, m in rows.items())
        print(f"  {w}: slowdown vs monolithic: {line}")
        print(f"      daemon speedup over page: {rows['page'].cycles/rows['daemon'].cycles:.2f}x")


def train_tiny():
    from repro.launch.train import train

    print("=== tiny LM training with the daemon movement engine ===")
    _, _, losses = train(
        "minicpm-2b", reduced=True, steps=10, global_batch=4, seq_len=64,
        movement="daemon", log_every=5,
    )
    print(f"  loss: {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    simulate()
    train_tiny()
