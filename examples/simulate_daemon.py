"""Reproduce the paper's headline numbers: geomean daemon-vs-page speedup and
access-cost reduction across the workload suite and network range.

    PYTHONPATH=src python examples/simulate_daemon.py
"""
import sys

sys.path.insert(0, "src")


def main():
    from repro.core.sim import paper_claims

    print("DaeMon vs page-granularity movement (paper claims: 2.39x perf, "
          "3.06x access cost)")
    r = paper_claims(n_accesses=20_000)
    for bw, row in r["per_bw"].items():
        per_w = " ".join(f"{w}:{v:.2f}" for w, v in row["per_workload"].items())
        print(f"  link bw = {bw:5.3f} x bus: perf {row['perf']:.2f}x  "
              f"cost {row['cost']:.2f}x   [{per_w}]")
    print(f"  GEOMEAN: perf {r['perf_speedup_geomean']:.2f}x  "
          f"access-cost {r['access_cost_reduction_geomean']:.2f}x")


if __name__ == "__main__":
    main()
