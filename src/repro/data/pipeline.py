"""Deterministic synthetic token pipeline: sharded, resumable, prefetched.

Production shape without external deps: tokens are a seeded hash of
(stream position), so any worker can materialize any slice of the global
stream independently — exactly what elastic restarts need (state = a single
int64 step counter; restoring to a different DP degree re-slices the same
stream).  A background thread keeps ``prefetch`` batches ready.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


def _hash_tokens(lo: np.ndarray, vocab: int, seed: int) -> np.ndarray:
    """splitmix64 over absolute positions -> [0, vocab)."""
    mix = (seed * 0x9E3779B97F4A7C15) % (1 << 64)
    with np.errstate(over="ignore"):
        z = (lo.astype(np.uint64) + np.uint64(mix)) * np.uint64(0xBF58476D1CE4E5B9)
    z ^= z >> np.uint64(27)
    z *= np.uint64(0x94D049BB133111EB)
    z ^= z >> np.uint64(31)
    return (z % np.uint64(vocab)).astype(np.int32)


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    dp_rank: int = 0
    dp_size: int = 1
    prefetch: int = 2


class TokenPipeline:
    """Iterator of {"tokens", "labels"} batches for this DP shard."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        assert cfg.global_batch % cfg.dp_size == 0
        self.cfg = cfg
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, cfg.prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # ---- deterministic materialization -------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        local_batch = cfg.global_batch // cfg.dp_size
        # absolute sequence index of each row in the global stream
        row0 = step * cfg.global_batch + self.cfg.dp_rank * local_batch
        rows = row0 + np.arange(local_batch)
        pos = rows[:, None] * (cfg.seq_len + 1) + np.arange(cfg.seq_len + 1)[None, :]
        toks = _hash_tokens(pos.reshape(-1), cfg.vocab_size, cfg.seed).reshape(
            local_batch, cfg.seq_len + 1
        )
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    # ---- background prefetch ------------------------------------------
    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put(self.batch_at(step), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self._q.get()
        self.step += 1
        return b

    def state(self) -> int:
        """Checkpointable state: the global step counter."""
        return self.step

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
