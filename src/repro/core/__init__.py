# The paper's primary contribution, two layers (see DESIGN.md §2):
#   repro.core.sim      — faithful event-driven DS simulator (DaeMon vs baselines)
#   repro.core.movement — TPU-native data-movement engine for the JAX framework
from repro.core import sim

__all__ = ["sim"]
