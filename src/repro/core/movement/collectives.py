"""DaeMon collective primitives (shard_map level).

These are the TPU realization of the paper's three techniques on explicit
collectives (DESIGN.md §2.2):

  compressed_all_gather     — link compression on page-granularity moves:
                              per-block int8 quantize -> gather -> dequant
                              (wire ~1.94x smaller than bf16, ~3.9x vs f32)
  compressed_grad_sync      — reduce-scatter with int8 link compression and
                              ERROR FEEDBACK (the residual re-enters the next
                              step's gradient, so compression error does not
                              accumulate — 1-bit-Adam-style)
  chunked_all_gather        — decoupled dual-granularity movement: the
                              critical chunk (needed-now slice) is emitted
                              first and uncompressed (sub-block queue), the
                              remaining page chunks follow compressed (page
                              queue); XLA's async collective streams overlap
                              them with compute in program order.

All primitives run inside ``shard_map`` over the DP axes.  Used by the
daemon train/serve steps, the movement benchmarks and examples; unit-tested
on 8 fake devices in tests/test_movement.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.block_quant import ops as bq

Axis = str


def _axis_size(axis_name: Axis) -> jax.Array:
    if hasattr(jax.lax, "axis_size"):  # jax >= 0.5
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def _flatten_pad(x: jax.Array, block: int) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block), pad


def compressed_all_gather(
    x: jax.Array, axis_name: Axis, *, compress: Optional[str] = "int8",
    tiled: bool = True,
) -> jax.Array:
    """All-gather x's leading dim over ``axis_name``; payload on the wire is
    int8 + per-128-block f32 scales when compress='int8'."""
    if compress is None or compress == "none":
        return jax.lax.all_gather(x, axis_name, tiled=tiled)
    if compress == "bf16":
        g = jax.lax.all_gather(x.astype(jnp.bfloat16), axis_name, tiled=tiled)
        return g.astype(x.dtype)
    assert compress == "int8", compress
    xf, pad = _flatten_pad(x, 128)
    q, s = bq.quantize(xf)
    qg = jax.lax.all_gather(q, axis_name, tiled=True)
    sg = jax.lax.all_gather(s, axis_name, tiled=True)
    full = bq.dequantize(qg, sg, x.dtype).reshape(-1)
    n = _axis_size(axis_name)
    if pad:
        per = xf.size  # padded elements per shard
        full = full.reshape(n, per)[:, : x.size].reshape(-1)
    return full.reshape((n * x.shape[0],) + x.shape[1:])


def compressed_grad_sync(
    g: jax.Array, axis_name: Axis, residual: Optional[jax.Array] = None,
    *, compress: Optional[str] = "int8",
) -> Tuple[jax.Array, jax.Array]:
    """Mean-reduce g over the DP axis with link compression + error feedback.

    Returns (g_mean, new_residual).  The wire carries int8 blocks via
    psum-of-dequantized shards implemented as all-to-all(int8) + local sum:
    each device quantizes its local gradient once, ships 1/n of it to every
    peer, and sums dequantized contributions for its own slice, then
    all-gathers the reduced slices (also int8).  residual holds what
    quantization dropped; it is added back before the next quantization.
    """
    if compress in (None, "none", "bf16"):
        dt = jnp.bfloat16 if compress == "bf16" else g.dtype
        gm = jax.lax.pmean(g.astype(dt), axis_name).astype(g.dtype)
        return gm, jnp.zeros((), g.dtype)

    assert compress == "int8", compress
    n = _axis_size(axis_name)
    if residual is not None and residual.ndim == g.ndim:
        g = g + residual.astype(g.dtype)

    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % (128 * n)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xf = flat.reshape(n, -1, 128)  # shard s for peer s

    q, s = bq.quantize(xf.reshape(-1, 128))
    q = q.reshape(n, -1, 128)
    s = s.reshape(n, -1)
    # error feedback: what int8 dropped, fed back next step
    deq_local = bq.dequantize(q.reshape(-1, 128), s.reshape(-1, 1), jnp.float32)
    new_res = (flat - deq_local.reshape(-1))[: g.size].reshape(g.shape).astype(jnp.float32)

    # ship int8 shards: all_to_all swaps the leading shard dim
    qt = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0, tiled=False)
    st = jax.lax.all_to_all(s, axis_name, split_axis=0, concat_axis=0, tiled=False)
    # each device now holds n peers' int8 contributions for ITS slice
    contrib = bq.dequantize(qt.reshape(-1, 128), st.reshape(-1, 1), jnp.float32)
    contrib = contrib.reshape(n, -1)
    my_slice = jnp.mean(contrib, axis=0)  # (slice_elems,)
    # gather the reduced slices back (compressed again on the wire)
    qg, sg = bq.quantize(my_slice.reshape(-1, 128))
    qall = jax.lax.all_gather(qg, axis_name, tiled=True)
    sall = jax.lax.all_gather(sg, axis_name, tiled=True)
    full = bq.dequantize(qall, sall, jnp.float32).reshape(-1)
    gm = full[: g.size].reshape(g.shape).astype(g.dtype)
    return gm, new_res


def chunked_all_gather(
    x: jax.Array, axis_name: Axis, *, page_chunks: int = 4,
    critical_rows: int = 0, compress_pages: str = "int8",
) -> jax.Array:
    """Dual-granularity gather of x (leading dim = rows) over the DP axis.

    The first ``critical_rows`` rows are the sub-block class: gathered FIRST,
    uncompressed (latency path).  The remainder is split into ``page_chunks``
    compressed page-class gathers.  Program order guarantees the critical
    gather is issued before any page chunk; on TPU, XLA's async collective
    scheduler overlaps the page chunks with downstream compute — this is the
    paper's fixed-rate bandwidth partition expressed as an HLO schedule.
    """
    rows = x.shape[0]
    n = _axis_size(axis_name)
    critical_rows = min(critical_rows, rows)
    parts = []  # (gathered, part_rows)
    if critical_rows:
        crit = jax.lax.all_gather(x[:critical_rows], axis_name, tiled=True)
        parts.append((crit, critical_rows))
    body_rows = rows - critical_rows
    if body_rows:
        page_chunks = max(1, min(page_chunks, body_rows))
        bounds = [critical_rows + (body_rows * i) // page_chunks for i in range(page_chunks + 1)]
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            if hi > lo:
                g = compressed_all_gather(x[lo:hi], axis_name, compress=compress_pages)
                parts.append((g, hi - lo))
    # each part is (n * part_rows, ...) shard-tiled; re-interleave to (n*rows, ...)
    stacked = jnp.concatenate(
        [p.reshape(n, r, *x.shape[1:]) for p, r in parts], axis=1
    )
    return stacked.reshape(n * rows, *x.shape[1:])
