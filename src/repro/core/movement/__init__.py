from repro.core.movement.collectives import (
    chunked_all_gather,
    compressed_all_gather,
    compressed_grad_sync,
)
from repro.core.movement.daemon_step import (
    DaemonState,
    init_abstract,
    init_state,
    make_daemon_train_step,
    state_shardings,
    working_copy,
)
from repro.core.movement.engine import (
    BASELINE,
    DAEMON_AGGRESSIVE,
    DAEMON_DEFAULT,
    MovementConfig,
    SelectionUnit,
)

__all__ = [
    "chunked_all_gather", "compressed_all_gather", "compressed_grad_sync",
    "DaemonState", "init_abstract", "init_state", "make_daemon_train_step",
    "state_shardings", "working_copy",
    "BASELINE", "DAEMON_AGGRESSIVE", "DAEMON_DEFAULT", "MovementConfig",
    "SelectionUnit",
]
