"""Data-movement engine configuration + the selection-granularity unit.

``MovementConfig`` is the TPU analogue of DaeMon's per-CC hardware config:
what the page class is compressed to, how bulk collectives are chunked, and
how much of each tensor rides the critical (sub-block) path.

``SelectionUnit`` is the paper's adaptive controller (§3-II) at the host
level: it watches the three roofline terms / measured step phases (the
"inflight buffer utilizations" of the TPU fabric) and picks the movement
config.  Decisions are hysteretic; a config change re-specializes the
compiled step (compile cache keyed on the config tuple), so flapping is
explicitly damped.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class MovementConfig:
    # page-class (bulk) movement
    param_gather: str = "bf16"  # f32 | bf16 | int8  — working-copy precision
    grad_sync: str = "bf16"  # f32 | bf16 | int8 (int8 => error feedback)
    expert_weights: str = "bf16"  # serving: int8 page-class expert/mlp weights
    page_chunks: int = 4  # bulk collective split factor (overlap granularity)
    # sub-block (critical) movement
    critical_rows: int = 0  # rows gathered first, uncompressed
    lines_per_page: int = 16  # nominal bandwidth partition ratio (doc'd knob)

    def cache_key(self) -> Tuple:
        return (
            self.param_gather, self.grad_sync, self.expert_weights,
            self.page_chunks, self.critical_rows,
        )


BASELINE = MovementConfig(param_gather="f32", grad_sync="f32", expert_weights="f32",
                          page_chunks=1, critical_rows=0)
DAEMON_DEFAULT = MovementConfig()
DAEMON_AGGRESSIVE = MovementConfig(grad_sync="int8", expert_weights="int8", page_chunks=8)


@dataclass
class SelectionUnit:
    """Hysteresis controller: collective-pressure signal -> MovementConfig.

    The signal is the collective roofline term divided by the compute term
    (dry-run: from launch.roofline; real HW: measured async-transfer time /
    step time).  High pressure -> compress harder + chunk more (pages are the
    bottleneck); low pressure -> back off to cheaper uncompressed movement
    (the paper's "schedule more pages under low bandwidth utilization").
    """

    hi: float = 1.0  # collective/compute ratio above which to escalate
    lo: float = 0.25  # ratio below which to relax
    hold_steps: int = 20  # hysteresis: min steps between changes
    _level: int = 1  # 0=baseline-ish, 1=default, 2=aggressive
    _last_change: int = -10**9
    history: list = field(default_factory=list)

    LEVELS = (
        MovementConfig(param_gather="bf16", grad_sync="bf16", page_chunks=1),
        DAEMON_DEFAULT,
        DAEMON_AGGRESSIVE,
    )

    def config(self) -> MovementConfig:
        return self.LEVELS[self._level]

    def observe(self, step: int, collective_s: float, compute_s: float) -> MovementConfig:
        ratio = collective_s / max(compute_s, 1e-12)
        self.history.append((step, ratio, self._level))
        if step - self._last_change >= self.hold_steps:
            if ratio > self.hi and self._level < 2:
                self._level += 1
                self._last_change = step
            elif ratio < self.lo and self._level > 0:
                self._level -= 1
                self._last_change = step
        return self.config()
