"""The DaeMon-integrated training step: mixed-precision ZeRO with page-class
link compression.

Differences from the baseline GSPMD step (launch/steps.py):

  * the f32 MASTER parameters live in the optimizer state (sharded exactly
    like the baseline params: FSDP over "data", TP over "model");
  * the forward/backward runs on a bf16 WORKING copy — so every
    per-layer parameter all-gather GSPMD emits inside the scan moves bf16,
    i.e. the page-granularity traffic is 2x smaller on the wire than the f32
    baseline (4x with expert_weights="int8" for MoE page-class tensors);
  * gradients arrive sharded (GSPMD reduce-scatters them to match the FSDP
    sharding) in bf16 — halving the gradient page traffic as well;
  * with grad_sync="int8", an explicit error-feedback residual (sharded,
    f32) is carried in the optimizer state and folded into the next step.

The collective-byte reduction is measured by the dry-run (§Perf: baseline vs
daemon rooflines); the selection unit (engine.py) picks the config level.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.movement.engine import DAEMON_DEFAULT, MovementConfig
from repro.kernels.block_quant import ops as bq
from repro.optim import adamw


class DaemonState(NamedTuple):
    adam: adamw.AdamWState  # m, v, step — f32, sharded like params
    master: Any  # f32 master params (sharded)
    residual: Any  # error-feedback residual (zeros unless grad_sync="int8")


def working_copy(master: Any, cfg_mv: MovementConfig) -> Any:
    """bf16 (or int8-roundtripped) working parameters from the f32 master."""

    def one(p):
        if cfg_mv.expert_weights == "int8" and p.ndim >= 3 and p.shape[-1] % 128 == 0:
            # page-class tensors (stacked expert/layer weights): int8 wire
            q, s = bq.quantize(p.astype(jnp.float32))
            return bq.dequantize(q, s, jnp.bfloat16)
        return p.astype(jnp.bfloat16)

    return jax.tree.map(one, master)


def init_state(master: Any) -> DaemonState:
    return DaemonState(
        adam=adamw.init(master),
        master=master,
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), master),
    )


def init_abstract(master: Any) -> DaemonState:
    sds = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), master)
    res = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), master)
    return DaemonState(adam=adamw.init_abstract(master), master=sds, residual=res)


def state_shardings(psh: Any, replicated) -> "DaemonState":
    """Sharding tree matching init_abstract (psh = param shardings)."""
    return DaemonState(
        adam=adamw.AdamWState(replicated, psh, psh),
        master=psh,
        residual=psh,
    )


def make_daemon_train_step(
    cfg: ModelConfig,
    *,
    sched: Callable,
    engine_cfg: Optional[MovementConfig] = None,
    num_microbatches: int = 1,
) -> Callable:
    mv = engine_cfg or DAEMON_DEFAULT
    from repro.launch.steps import _microbatched_grads

    def train_step(params_bf16, state: DaemonState, batch):
        # params_bf16 is the donated working copy from the previous step;
        # grads are computed against it (GSPMD gathers bf16 pages per layer)
        grads, metrics = _microbatched_grads(cfg, params_bf16, batch, num_microbatches)

        if mv.grad_sync == "int8":
            # error feedback: dropped quantization error re-enters here
            def fold(g, r):
                g32 = g.astype(jnp.float32) + r
                if g32.ndim >= 2 and g32.shape[-1] % 128 == 0:
                    q, s = bq.quantize(g32)
                    deq = bq.dequantize(q, s, jnp.float32)
                    return deq, g32 - deq
                return g32, jnp.zeros_like(g32)

            pairs = jax.tree.map(fold, grads, state.residual)
            grads = jax.tree.map(lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
            residual = jax.tree.map(lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        else:
            residual = state.residual

        lr = sched(state.adam.step)
        master, adam_state, om = adamw.update(grads, state.adam, state.master, lr)
        new_params = working_copy(master, mv)
        return new_params, DaemonState(adam_state, master, residual), {**metrics, **om}

    return train_step
