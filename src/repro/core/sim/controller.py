"""Pluggable movement controllers (DESIGN.md §2.12): the selection unit,
the issue throttle, and the compression triggers as one replaceable
decision layer.

DaeMon's adaptive granularity selection (paper §3-II), the inflight-buffer
throttle, and the congestion-triggered link compression (§3-III) are all
*decisions over the same observation vector*: line/page inflight-buffer
utilization, the CC->MC uplink backlog, and the recent per-class drain
rates.  This module factors those decisions out of the two engines into a
:class:`MovementController` with a ``@register_controller`` registry, so
the thresholds stop being scattered constants and become a swept axis
(``SimConfig.controller``), a policy component
(``MovementPolicy.controller``), and a serving per-pool override
(``cfg.serving_prefill_controller`` / ``serving_decode_controller``).

Three controllers ship:

``fixed``
    The legacy constants, verbatim — bit-identical to every committed
    golden and gated geomean.  Its :meth:`~MovementController.decide` is
    exactly the inline expressions the engines used to carry.
``adaptive``
    Tracks the coalesce density (the fraction of remote misses that land
    on a page already in flight — the page-density signature of real
    tiled kernel streams) and the per-class arrival gaps in EWMAs, plus
    the live uplink backlog, and backs line racing off in page-dense
    phases where redundant line races only steal the reserved line share
    from the pages that actually carry the data.  The first policy with
    headroom on the fig8 kernel traces, where ``fixed`` daemon collapses
    to ~1.0x vs page.
``tuned``
    Per-workload ``(page_fast, throttle_hi)`` thresholds fitted offline
    by ``benchmarks/fit_controller.py`` sweeping the batch engine;
    unknown workloads fall back to the fixed constants.

Contract with the engines (the bit-parity rule): only the ``observe_*``
hooks may mutate controller state; :meth:`~MovementController.decide` is
pure given that state.  Both engines deliver the same observe sequence
(their event orders are transcribed lockstep), so a controller behaves
identically under the oracle and the batch core even when the two call
``decide`` a different number of times.

This module is a leaf: it imports nothing from the sim package, so
config.py / policy.py / both engines can import it freely.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple

# inflight-page utilization below which pages drain fast (paper §3-II/III:
# the selection unit and the compression trigger both key off this).  The
# single source of truth — engine.py re-exports it for the batch engine
# and tests/test_controller.py drift-locks the value.
PAGE_FAST = 0.3


def selection_races_line(lu: float, pu: float) -> bool:
    """Adaptive selection unit (paper §3-II): race a line for a coalesced
    miss only when the page queue is congested (the line is the
    critical-path fast path) and the line buffer has room."""
    return pu > PAGE_FAST and lu < 1.0


class Observation(NamedTuple):
    """What a controller sees at a decision point.

    ``lu``/``pu`` are the line/page inflight-buffer utilizations (pending
    entries / buffer capacity); ``uplink_backlog`` is the CC->MC uplink
    backlog in bytes toward the MC the decision concerns (0.0 when the
    uplink is not modeled or the controller's ``needs_uplink`` is False —
    the engines skip the backlog computation on the hot path for
    controllers that never read it)."""

    t: float
    lu: float
    pu: float
    uplink_backlog: float = 0.0


class Decision(NamedTuple):
    """A controller's answer at a decision point.  Call sites read only
    the fields their site concerns — the unread fields cost nothing."""

    race_line: bool       # coalesced miss: race a line on the critical path?
    issue_line: bool      # triggering miss / retry: issue the line movement?
    issue_page: bool      # triggering miss / retry: issue the page movement?
    compress: bool        # demand page + legacy writeback: engage compression?
    compress_writeback: bool  # uplink writeback: compress before sending?


class MovementController:
    """Base controller: the observe/decide split both engines rely on.

    Subclasses override :meth:`decide` (pure) and any ``observe_*`` hook
    they need (the only methods allowed to mutate state).  ``needs_uplink``
    tells the engines whether to compute ``Observation.uplink_backlog``
    outside the writeback path — leave it False unless ``decide`` reads
    the backlog, it keeps a link-heap scan off the miss hot path."""

    name = "?"
    description = ""
    needs_uplink = False

    def __init__(self, cfg, workload: str = ""):
        self.cfg = cfg
        self.workload = workload

    # -- observation hooks (the only state mutators) --------------------
    def observe_line(self, t: float) -> None:
        """A line movement arrived at the CC at time ``t``."""

    def observe_page(self, t: float) -> None:
        """A page movement arrived at the CC at time ``t``."""

    def observe_miss(self, coalesced: bool) -> None:
        """A remote miss reached the movement unit; ``coalesced`` is True
        when its page was already in flight."""

    # -- the pure decision ----------------------------------------------
    def decide(self, obs: Observation) -> Decision:
        raise NotImplementedError

    def thresholds(self) -> Dict[str, float]:
        """The controller's operating thresholds (``run.py --list``)."""
        return {}


# --------------------------------------------------------------------------
# registry (the policy/workload/topology registry idiom)
# --------------------------------------------------------------------------

_CONTROLLERS: Dict[str, Callable[..., MovementController]] = {}


def register_controller(cls=None, *, name: str = "", overwrite: bool = False):
    """Register a MovementController class (decorator or direct call).
    The registered name is ``cls.name`` unless ``name`` overrides it."""

    def reg(c):
        key = name or c.name
        if not key or key == "?":
            raise ValueError(f"controller {c!r} has no name")
        if key in _CONTROLLERS and not overwrite:
            raise ValueError(f"controller {key!r} already registered "
                             f"(pass overwrite=True to replace)")
        _CONTROLLERS[key] = c
        return c

    return reg(cls) if cls is not None else reg


def unregister_controller(name: str) -> None:
    _CONTROLLERS.pop(name, None)


def get_controller(name: str) -> Callable[..., MovementController]:
    """The registered controller class for ``name``; raises KeyError with
    the known choices (fail-fast for config/sweep/CLI validation)."""
    try:
        return _CONTROLLERS[name]
    except KeyError:
        raise KeyError(f"unknown controller {name!r}; "
                       f"choose from {available_controllers()}") from None


def available_controllers() -> list:
    return sorted(_CONTROLLERS)


def make_controller(name: str, cfg, workload: str = "") -> MovementController:
    """Instantiate one per-CC controller (each CC gets its own state)."""
    return get_controller(name)(cfg, workload)


def resolve_controller(policy, cfg) -> str:
    """The controller name a CC runs: the policy's explicit component
    wins (so serving per-pool overrides beat the sweep axis), then the
    config's, then the legacy ``fixed``."""
    return (getattr(policy, "controller", None)
            or getattr(cfg, "controller", None)
            or "fixed")


# --------------------------------------------------------------------------
# the three shipped controllers
# --------------------------------------------------------------------------


@register_controller
class FixedController(MovementController):
    """The legacy constants, verbatim: ``decide`` reproduces exactly the
    inline expressions the engines carried before the refactor, so every
    committed golden and gated geomean is bit-identical under it."""

    name = "fixed"
    description = ("legacy constants: race above PAGE_FAST, throttle at "
                   "page_throttle_hi, compress on buffer/backlog pressure")

    def decide(self, obs: Observation) -> Decision:
        cfg = self.cfg
        return Decision(
            race_line=selection_races_line(obs.lu, obs.pu),
            issue_line=obs.lu < 1.0,
            issue_page=obs.pu < cfg.page_throttle_hi,
            compress=obs.pu > PAGE_FAST,
            compress_writeback=obs.uplink_backlog > cfg.page_bytes,
        )

    def thresholds(self) -> Dict[str, float]:
        return {"page_fast": PAGE_FAST,
                "throttle_hi": self.cfg.page_throttle_hi}


@register_controller
class AdaptiveController(MovementController):
    """Backs line racing off in page-dense phases.

    State (observe hooks only): an EWMA of the coalesce density — the
    fraction of remote misses whose page is already in flight — and EWMAs
    of the line/page arrival gaps (the per-class drain rates).  Real
    tiled kernel streams coalesce ~60 of 64 lines per page (density
    ~0.95+) while the synthetic suite's sparse sources sit near 0, so the
    density EWMA separates the two regimes cleanly.

    Decisions: above ``race_backoff`` density, coalesced misses stop
    racing redundant lines — each race steals the reserved line share
    from the page that already carries the data.  Only the *redundant*
    races back off: a non-coalesced (triggering) miss still issues its
    line, because that line IS the critical path (suppressing it was
    measured strictly worse on every captured kernel).  A deeply
    backlogged uplink (> ``uplink_backoff_pages`` pages of bytes) also
    suppresses racing — every raced line costs a request packet on the
    congested reverse path.  Everything else (throttle, compression)
    stays at the fixed thresholds, so on the synthetic suite — where the
    density never crosses the backoff — ``adaptive`` is
    decision-identical to ``fixed``."""

    name = "adaptive"
    description = ("EWMA coalesce-density + drain-rate tracker; stops "
                   "racing lines in page-dense (tiled-kernel) phases")
    needs_uplink = True

    # EWMA smoothing for the density signal: ~1/alpha misses of memory
    alpha = 0.02
    # smoothing for the per-class arrival-gap (drain-rate) trackers
    gap_alpha = 0.05
    # density above which coalesced misses stop racing lines
    race_backoff = 0.60
    # uplink backlog (in pages) above which racing is suppressed
    uplink_backoff_pages = 4.0

    def __init__(self, cfg, workload: str = ""):
        super().__init__(cfg, workload)
        self.density = 0.0
        self.line_gap = 0.0
        self.page_gap = 0.0
        self._last_line = 0.0
        self._last_page = 0.0

    def observe_line(self, t: float) -> None:
        a = self.gap_alpha
        self.line_gap += a * ((t - self._last_line) - self.line_gap)
        self._last_line = t

    def observe_page(self, t: float) -> None:
        a = self.gap_alpha
        self.page_gap += a * ((t - self._last_page) - self.page_gap)
        self._last_page = t

    def observe_miss(self, coalesced: bool) -> None:
        self.density += self.alpha * ((1.0 if coalesced else 0.0)
                                      - self.density)

    def decide(self, obs: Observation) -> Decision:
        cfg = self.cfg
        dense = self.density > self.race_backoff
        up_hot = obs.uplink_backlog > self.uplink_backoff_pages * cfg.page_bytes
        return Decision(
            race_line=(selection_races_line(obs.lu, obs.pu)
                       and not dense and not up_hot),
            issue_line=obs.lu < 1.0,
            issue_page=obs.pu < cfg.page_throttle_hi,
            compress=obs.pu > PAGE_FAST,
            compress_writeback=obs.uplink_backlog > cfg.page_bytes,
        )

    def thresholds(self) -> Dict[str, float]:
        return {"page_fast": PAGE_FAST,
                "throttle_hi": self.cfg.page_throttle_hi,
                "race_backoff": self.race_backoff,
                "uplink_backoff_pages": self.uplink_backoff_pages,
                "alpha": self.alpha}


# Per-workload (page_fast, throttle_hi) fitted offline by
# benchmarks/fit_controller.py sweeping the batch engine (daemon cycles at
# the congested end of the paper's network range, link_bw_frac=0.125).
# Regenerate with:
#   PYTHONPATH=src python benchmarks/fit_controller.py
# Workloads absent from the table run the fixed constants.
TUNED_THRESHOLDS: Dict[str, tuple] = {
    "pr": (0.40, 0.75),
    "bf": (0.10, 0.90),
    "ts": (0.20, 0.90),
    "nw": (0.10, 0.90),
    "dr": (0.50, 0.90),
    "pf": (0.20, 0.90),
    "st": (0.10, 0.50),
    "ml": (0.30, 0.75),
    "ph": (0.10, 0.65),
    "wh": (0.10, 0.50),
    "fa_prefill": (0.40, 0.65),
    "fa_decode": (0.30, 0.50),
    "mamba_fwd": (0.50, 0.90),
    "bq_quant": (0.30, 0.50),
}


@register_controller
class TunedController(MovementController):
    """Per-workload thresholds from :data:`TUNED_THRESHOLDS` substituted
    into the fixed decision formulas; the fit is offline (batch-engine
    sweep in ``benchmarks/fit_controller.py``), the controller itself is
    stateless like ``fixed``."""

    name = "tuned"
    description = ("per-workload (page_fast, throttle_hi) fitted offline "
                   "on the batch engine; fixed constants elsewhere")

    def __init__(self, cfg, workload: str = ""):
        super().__init__(cfg, workload)
        self.page_fast, self.throttle_hi = TUNED_THRESHOLDS.get(
            workload, (PAGE_FAST, cfg.page_throttle_hi))

    def decide(self, obs: Observation) -> Decision:
        return Decision(
            race_line=obs.pu > self.page_fast and obs.lu < 1.0,
            issue_line=obs.lu < 1.0,
            issue_page=obs.pu < self.throttle_hi,
            compress=obs.pu > self.page_fast,
            compress_writeback=obs.uplink_backlog > self.cfg.page_bytes,
        )

    def thresholds(self) -> Dict[str, float]:
        return {"page_fast": self.page_fast,
                "throttle_hi": self.throttle_hi}
