"""Event-driven simulator of a disaggregated system (CCs + MCs + network).

Data movement is governed by a composable :class:`~repro.core.sim.policy.
MovementPolicy` (DESIGN.md §2.6): the engine dispatches on the policy's
orthogonal *components* — ``granularity`` (none/line/page/both/adaptive),
``partitioning`` (fifo/dual), ``compression`` (off/link), ``throttle`` —
never on policy names, so registering a new composition requires no engine
edits.  The paper's six schemes are the registered legacy compositions
(``local``, ``page``, ``page_free``, ``cacheline``, ``both``, ``daemon``),
bit-identical to the pre-registry engine.

The FIFO partitioning is store-and-forward (this is where critical lines
queue behind concurrently-moved pages — the paper's core pathology).  The
dual partitioning is DaeMon's fluid dual-queue: when both queues are busy
the sub-block queue drains at a fixed ``line_share`` of the bandwidth, i.e.
the paper's queue controller serving lines at a higher predefined fixed rate.

Scenario axes: every link optionally carries a :class:`LinkSchedule` — a
piecewise-constant per-epoch bandwidth/latency multiplier modeling runtime
network variability (DESIGN.md §5) — pages/lines are interleaved across
``n_mcs`` independent MC links per ``SimConfig.mc_interleave`` (DESIGN.md
§2.3), and ``n_ccs`` compute complexes, each with its own cores/LLC/local
page cache and (for daemon) its own engines, contend for the SAME per-MC
downlinks through per-CC flow arbitration (DESIGN.md §2.5).  ``n_ccs=1``
keeps the legacy single-CC links and reproduces the legacy model
bit-for-bit.

With ``SimConfig.uplink_bw`` set, the CC->MC direction becomes a
first-class contended resource too (DESIGN.md §2.7): line/page request
packets (~``header_bytes`` each) and dirty-page writebacks queue on a
per-MC *uplink* built from the same link machinery, arbitrated per the
policy's ``uplink`` component ('line' class = request packets, 'page'
class = writeback bulk), and CC-side writeback compression keys off the
uplink backlog.  ``uplink_bw=None`` (default) is the legacy model —
requests folded into ``net_lat``, writebacks injected into the downlink —
bit-identical to every committed golden.
"""
from __future__ import annotations

import heapq
import itertools
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sim.config import Metrics, SimConfig
# PAGE_FAST / selection_races_line moved to controller.py (§2.12); the
# re-export keeps engine_batch.py and existing imports working
from repro.core.sim.controller import (  # noqa: F401
    PAGE_FAST,
    Observation,
    make_controller,
    resolve_controller,
    selection_races_line,
)
from repro.core.sim.fabric import Fabric, PortSpec, build_topology
from repro.core.sim.memside import make_memside
from repro.core.sim.policy import get_policy
from repro.core.sim.trace import Trace, compressibility_of


# --------------------------------------------------------------------------
# event engine
# --------------------------------------------------------------------------


class Engine:
    def __init__(self):
        self.heap: List = []
        self._seq = itertools.count()
        self.now = 0.0

    def at(self, t: float, fn: Callable[[float], None]) -> None:
        heapq.heappush(self.heap, (t, next(self._seq), fn))

    def run(self, until: Optional[float] = None) -> float:
        """Drain the event heap; with ``until`` set, stop before the first
        event past that time (the serving layer's horizon cut, §2.9) —
        remaining events stay queued and ``now`` is the last fired time."""
        while self.heap:
            if until is not None and self.heap[0][0] > until:
                break
            t, _, fn = heapq.heappop(self.heap)
            self.now = t
            fn(t)
        return self.now


# --------------------------------------------------------------------------
# pure link/selection math, shared with the batch engine
# --------------------------------------------------------------------------
#
# These are the arithmetic kernels of the simulator — no state, no events.
# Both the oracle classes below and engine_batch.py call them, so the two
# engines cannot drift apart on the float expressions that decide completion
# times, fluid shares, placement, or selection-unit behaviour.  Any change
# here changes BOTH engines identically (and the committed goldens).
# PAGE_FAST and selection_races_line live in controller.py since the
# MovementController refactor (§2.12) and are re-exported above.


def fifo_finish(start: float, size: float, bw: float,
                sched: Optional["LinkSchedule"]) -> float:
    """Completion time of ``size`` bytes starting at ``start`` on a FIFO
    link, integrating the piecewise-constant bandwidth schedule across
    epoch boundaries (a plain ``size/bw`` when the schedule is inert)."""
    if sched is None or not sched.bw_active:
        return start + size / bw
    t, rem = start, size
    while True:
        b = bw * sched.bw_mult(t)
        nb = sched.next_boundary(t)
        cap = b * (nb - t)
        if rem <= cap:
            return t + rem / b
        rem -= cap
        t = nb


def fair_split(n_active: int, bw: float) -> float:
    """Per-lane rate under fluid fair share: k backlogged lanes each drain
    at bw/k (the fluid limit of round-robin packet arbitration)."""
    return bw / n_active


def class_share_split(n_lines: int, n_pages: int, bw: float,
                      line_share: float) -> Tuple[float, float]:
    """Per-lane (line_rate, page_rate) under DaeMon's fixed-rate queue
    controller: the line class keeps ``line_share`` of ``bw`` whenever both
    classes are backlogged, all of it when pages are idle (and vice versa);
    within a class the backlogged lanes share equally."""
    if n_lines and n_pages:
        lb, pb = line_share * bw, (1.0 - line_share) * bw
    elif n_lines:
        lb, pb = bw, 0.0
    else:
        lb, pb = 0.0, bw
    return (lb / n_lines if n_lines else 0.0,
            pb / n_pages if n_pages else 0.0)


def mc_place(page: int, n_mcs: int, mode: str) -> int:
    """Page -> MC link placement (DESIGN.md §2.3)."""
    if n_mcs <= 1:
        return 0
    if mode == "single":
        return 0
    if mode == "hash":  # Fibonacci hash: immune to power-of-two strides
        return (((page * 0x9E3779B1) & 0xFFFFFFFF) >> 7) % n_mcs
    return page % n_mcs


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------


class LRU:
    """LRU cache over fixed-size entries; returns evicted (tag, dirty)."""

    __slots__ = ("cap", "d")

    def __init__(self, capacity: int):
        self.cap = max(1, capacity)
        self.d: OrderedDict = OrderedDict()

    def access(self, tag, dirty: bool = False) -> bool:
        if tag in self.d:
            self.d.move_to_end(tag)
            if dirty:
                self.d[tag] = True
            return True
        return False

    def insert(self, tag, dirty: bool = False):
        if tag in self.d:
            self.d.move_to_end(tag)
            self.d[tag] = self.d[tag] or dirty
            return None
        self.d[tag] = dirty
        if len(self.d) > self.cap:
            return self.d.popitem(last=False)
        return None

    def __contains__(self, tag):
        return tag in self.d


# --------------------------------------------------------------------------
# links
# --------------------------------------------------------------------------


class LinkSchedule:
    """Time-varying network model (DESIGN.md §5): piecewise-constant
    multipliers resampled once per ``period`` cycles, modeling fabric
    congestion — available bandwidth dips below nominal capacity
    (mult = 1 - bw_jitter*U[0,1), floored at 0.05) and latency spikes above
    the propagation floor (mult = 1 + lat_jitter*U[0,1)).

    Multipliers are a pure function of (seed, epoch index), so the "network
    weather" is identical across schemes, runs, and worker processes — a fair
    A/B environment by construction.  With both jitters zero the schedule is
    inert and links reproduce the legacy fixed-network results bit-for-bit.
    """

    __slots__ = ("period", "bw_jitter", "lat_jitter", "seed", "_cache")

    def __init__(self, period: int, bw_jitter: float, lat_jitter: float, seed: int = 0):
        self.period = max(1, int(period))
        self.bw_jitter = float(bw_jitter)
        self.lat_jitter = float(lat_jitter)
        self.seed = seed
        self._cache: Dict[int, Tuple[float, float]] = {}

    @property
    def bw_active(self) -> bool:
        return self.bw_jitter > 0.0

    @property
    def lat_active(self) -> bool:
        return self.lat_jitter > 0.0

    def _mults(self, epoch: int) -> Tuple[float, float]:
        m = self._cache.get(epoch)
        if m is None:
            rng = np.random.default_rng((self.seed, epoch))
            bw = max(0.05, 1.0 - self.bw_jitter * rng.random())
            lat = 1.0 + self.lat_jitter * rng.random()
            m = self._cache[epoch] = (bw, lat)
        return m

    def bw_mult(self, t: float) -> float:
        return self._mults(int(t // self.period))[0] if self.bw_active else 1.0

    def lat_mult(self, t: float) -> float:
        return self._mults(int(t // self.period))[1] if self.lat_active else 1.0

    def next_boundary(self, t: float) -> float:
        return (int(t // self.period) + 1) * float(self.period)


class FifoLink:
    """Store-and-forward FIFO: one queue, transfers fully serialize.

    Single-CC only (``flow`` is accepted for call-site uniformity and
    ignored); multi-CC systems use :class:`SharedFifoLink`."""

    def __init__(self, eng: Engine, bw: float, sched: Optional[LinkSchedule] = None):
        self.eng = eng
        self.bw = bw
        self.sched = sched
        self.busy_until = 0.0
        self.bytes = 0.0

    def _finish(self, start: float, size: float) -> float:
        """Completion time of ``size`` bytes starting at ``start``, integrating
        the piecewise-constant bandwidth schedule across epoch boundaries."""
        return fifo_finish(start, size, self.bw, self.sched)

    def send(self, t: float, size: float, cb: Callable[[float], None],
             cls: str = "line", flow: int = 0):
        start = max(t, self.busy_until)
        done = self._finish(start, size)
        self.busy_until = done
        self.bytes += size
        self.eng.at(done, cb)

    def backlog(self, t: float) -> float:
        """Outstanding bytes not yet transmitted (congestion signal,
        DESIGN.md §2.7): residual busy time x nominal bandwidth."""
        return max(0.0, self.busy_until - t) * self.bw


class DualQueueLink:
    """DaeMon's decoupled queues: fluid bandwidth partition between the
    sub-block (line) queue and the page queue.  Within a queue transfers
    serialize FIFO; across queues the line queue gets ``line_share`` of the
    bandwidth whenever it is non-empty (and all of it when pages are idle).

    Single-CC only (``flow`` ignored); multi-CC systems use
    :class:`SharedDualQueueLink`."""

    def __init__(self, eng: Engine, bw: float, line_share: float,
                 sched: Optional[LinkSchedule] = None):
        self.eng = eng
        self.bw = bw
        self.sched = sched
        self.share = {"line": line_share, "page": 1.0 - line_share}
        self.q: Dict[str, deque] = {"line": deque(), "page": deque()}
        self.head_rem: Dict[str, float] = {"line": 0.0, "page": 0.0}
        self.cb: Dict[str, Optional[Callable]] = {"line": None, "page": None}
        self.last = 0.0
        self.epoch = 0
        self.bytes = 0.0

    def _bw_at(self, t: float) -> float:
        s = self.sched
        return self.bw * s.bw_mult(t) if s is not None and s.bw_active else self.bw

    def _rates(self, t: float) -> Dict[str, float]:
        la = self.head_rem["line"] > 0
        pa = self.head_rem["page"] > 0
        if not (la or pa):
            return {"line": 0.0, "page": 0.0}
        lr, pr = class_share_split(1 if la else 0, 1 if pa else 0,
                                   self._bw_at(t), self.share["line"])
        return {"line": lr, "page": pr}

    def _advance(self, t: float):
        sched = self.sched
        varying = sched is not None and sched.bw_active
        if self.head_rem["line"] <= 0 and self.head_rem["page"] <= 0:
            self.last = max(self.last, t)  # idle link: skip epoch walking
            return
        while self.last < t:
            seg_end = min(t, sched.next_boundary(self.last)) if varying else t
            dt = seg_end - self.last
            if dt > 0:
                rates = self._rates(self.last)
                for c in ("line", "page"):
                    if self.head_rem[c] > 0:
                        self.head_rem[c] = max(0.0, self.head_rem[c] - rates[c] * dt)
            self.last = seg_end

    def _schedule(self, t: float):
        self.epoch += 1
        epoch = self.epoch
        rates = self._rates(t)
        best = None
        for c in ("line", "page"):
            if self.head_rem[c] > 0 and rates[c] > 0:
                eta = t + self.head_rem[c] / rates[c]
                if best is None or eta < best[0]:
                    best = (eta, c)
        if best is None:
            return
        eta, c = best
        # ETAs computed with this epoch's rate are invalid past the next
        # bandwidth-schedule boundary: fire there instead and re-derive the
        # rates (the fire handler reschedules any unfinished head).
        if self.sched is not None and self.sched.bw_active:
            nb = self.sched.next_boundary(t)
            if eta > nb:
                eta = nb

        def fire(tt: float, _c=c, _epoch=epoch):
            if _epoch != self.epoch:
                return  # stale
            self._advance(tt)
            # epsilon is in *bytes*: float residue from rate*dt rounding can
            # exceed 1e-9 while eta rounds to the same timestamp (no progress,
            # infinite event storm).  1e-3 bytes is far below any packet size.
            if self.head_rem[_c] > 1e-3:
                self._schedule(tt)
                return
            cb = self.cb[_c]
            self._pop_next(_c)
            self._schedule(tt)
            if cb:
                cb(tt)

        self.eng.at(eta, fire)

    def _pop_next(self, c: str):
        if self.q[c]:
            size, cb = self.q[c].popleft()
            self.head_rem[c] = size
            self.cb[c] = cb
        else:
            self.head_rem[c] = 0.0
            self.cb[c] = None

    def _flush(self, t: float):
        """Complete any head that already drained to zero during _advance —
        its scheduled fire event may be stale and must not drop the callback."""
        for c in ("line", "page"):
            while self.cb[c] is not None and self.head_rem[c] <= 1e-3:
                cb = self.cb[c]
                self._pop_next(c)
                cb(t)

    def send(self, t: float, size: float, cb: Callable[[float], None],
             cls: str = "line", flow: int = 0):
        self._advance(t)
        self._flush(t)
        self.bytes += size
        if self.cb[cls] is not None:
            self.q[cls].append((size, cb))
        else:
            self.head_rem[cls] = size
            self.cb[cls] = cb
        self._schedule(t)

    def backlog(self, t: float) -> float:
        """Outstanding bytes across both classes (congestion signal,
        DESIGN.md §2.7).  ``head_rem`` is exact as of the last ``_advance``;
        staleness only overstates the backlog, which is safe for a trigger."""
        q = sum(sz for d in self.q.values() for sz, _ in d)
        return q + sum(max(0.0, r) for r in self.head_rem.values())


class SharedLink:
    """Multi-flow generalization of :class:`DualQueueLink`'s fluid machinery
    (DESIGN.md §2.5): one FIFO *lane* per channel (a channel is a CC flow,
    or a (flow, class) pair), and an arbitration policy — ``_split`` — that
    divides the instantaneous link bandwidth across the backlogged lanes.
    Within a lane transfers serialize FIFO; across lanes the fluid shares
    are re-derived whenever a head completes, a send arrives, or a
    bandwidth-schedule epoch boundary passes.

    Only instantiated for ``n_ccs > 1`` systems: single-CC runs keep the
    legacy FifoLink/DualQueueLink code paths byte-for-byte.
    """

    def __init__(self, eng: Engine, bw: float, channels: Sequence[Hashable],
                 sched: Optional[LinkSchedule] = None):
        self.eng = eng
        self.bw = bw
        self.sched = sched
        self.channels: Tuple[Hashable, ...] = tuple(channels)
        self.q: Dict[Hashable, deque] = {c: deque() for c in self.channels}
        self.head_rem: Dict[Hashable, float] = dict.fromkeys(self.channels, 0.0)
        self.cb: Dict[Hashable, Optional[Callable]] = dict.fromkeys(self.channels)
        self.last = 0.0
        self.epoch = 0
        self.bytes = 0.0

    # -- arbitration policy (subclasses) --
    def _chan(self, flow: int, cls: str) -> Hashable:
        raise NotImplementedError

    def _split(self, active: List[Hashable], bw: float) -> Dict[Hashable, float]:
        """Divide ``bw`` across the backlogged channels ``active``."""
        raise NotImplementedError

    # -- fluid machinery (generalized from DualQueueLink) --
    def _bw_at(self, t: float) -> float:
        s = self.sched
        return self.bw * s.bw_mult(t) if s is not None and s.bw_active else self.bw

    def _rates(self, t: float) -> Dict[Hashable, float]:
        active = [c for c in self.channels if self.head_rem[c] > 0]
        rates = dict.fromkeys(self.channels, 0.0)
        if active:
            rates.update(self._split(active, self._bw_at(t)))
        return rates

    def _advance(self, t: float):
        sched = self.sched
        varying = sched is not None and sched.bw_active
        if all(self.head_rem[c] <= 0 for c in self.channels):
            self.last = max(self.last, t)  # idle link: skip epoch walking
            return
        while self.last < t:
            seg_end = min(t, sched.next_boundary(self.last)) if varying else t
            dt = seg_end - self.last
            if dt > 0:
                rates = self._rates(self.last)
                for c in self.channels:
                    if self.head_rem[c] > 0:
                        self.head_rem[c] = max(0.0, self.head_rem[c] - rates[c] * dt)
            self.last = seg_end

    def _schedule(self, t: float):
        self.epoch += 1
        epoch = self.epoch
        rates = self._rates(t)
        best = None
        for c in self.channels:
            if self.head_rem[c] > 0 and rates[c] > 0:
                eta = t + self.head_rem[c] / rates[c]
                if best is None or eta < best[0]:
                    best = (eta, c)
        if best is None:
            return
        eta, c = best
        if self.sched is not None and self.sched.bw_active:
            nb = self.sched.next_boundary(t)
            if eta > nb:
                eta = nb  # re-derive rates at the epoch boundary

        def fire(tt: float, _c=c, _epoch=epoch):
            if _epoch != self.epoch:
                return  # stale
            self._advance(tt)
            if self.head_rem[_c] > 1e-3:  # epsilon in bytes, as DualQueueLink
                self._schedule(tt)
                return
            # several lanes can drain at the same instant under fair shares:
            # complete every finished head, not just the scheduled one
            done = []
            for ch in self.channels:
                if self.cb[ch] is not None and self.head_rem[ch] <= 1e-3:
                    done.append(self.cb[ch])
                    self._pop_next(ch)
            self._schedule(tt)
            for fn in done:
                fn(tt)

        self.eng.at(eta, fire)

    def _pop_next(self, c: Hashable):
        if self.q[c]:
            size, cb = self.q[c].popleft()
            self.head_rem[c] = size
            self.cb[c] = cb
        else:
            self.head_rem[c] = 0.0
            self.cb[c] = None

    def _flush(self, t: float):
        for c in self.channels:
            while self.cb[c] is not None and self.head_rem[c] <= 1e-3:
                cb = self.cb[c]
                self._pop_next(c)
                cb(t)

    def send(self, t: float, size: float, cb: Callable[[float], None],
             cls: str = "line", flow: int = 0):
        self._advance(t)
        self._flush(t)
        self.bytes += size
        c = self._chan(flow, cls)
        if self.cb[c] is not None:
            self.q[c].append((size, cb))
        else:
            self.head_rem[c] = size
            self.cb[c] = cb
        self._schedule(t)

    def backlog(self, t: float) -> float:
        """Outstanding bytes across all lanes (congestion signal, §2.7)."""
        q = sum(sz for d in self.q.values() for sz, _ in d)
        return q + sum(max(0.0, r) for r in self.head_rem.values())


class SharedFifoLink(SharedLink):
    """Baseline MC downlink shared by ``n_flows`` CCs: one store-and-forward
    FIFO lane per CC, fluid fair share across backlogged lanes (k active
    flows each drain at bw/k — the fluid limit of round-robin packet
    arbitration).  Lines still serialize behind pages *within* a CC's lane
    (the paper's single-flow pathology), and a page burst from one CC
    additionally cuts every other CC's drain rate — the multi-CC contention
    the paper's scalability goal targets."""

    def __init__(self, eng: Engine, bw: float, n_flows: int,
                 sched: Optional[LinkSchedule] = None):
        super().__init__(eng, bw, tuple(range(n_flows)), sched)

    def _chan(self, flow: int, cls: str) -> Hashable:
        return flow

    def _split(self, active: List[Hashable], bw: float) -> Dict[Hashable, float]:
        r = fair_split(len(active), bw)
        return {c: r for c in active}


class SharedDualQueueLink(SharedLink):
    """DaeMon MC downlink shared by ``n_flows`` CCs: the line *class* keeps
    its fixed ``line_share`` of the bandwidth whenever any CC has a line in
    flight (the paper's fixed-rate queue controller, applied system-wide),
    and within each class the backlogged CC flows share the class bandwidth
    equally.  One CC's page burst therefore cannot delay another CC's
    critical lines beyond the fair division of the reserved line share."""

    def __init__(self, eng: Engine, bw: float, line_share: float, n_flows: int,
                 sched: Optional[LinkSchedule] = None):
        self.line_share = line_share
        channels = [(f, c) for f in range(n_flows) for c in ("line", "page")]
        super().__init__(eng, bw, channels, sched)

    def _chan(self, flow: int, cls: str) -> Hashable:
        return (flow, cls)

    def _split(self, active: List[Hashable], bw: float) -> Dict[Hashable, float]:
        lines = [c for c in active if c[1] == "line"]
        pages = [c for c in active if c[1] == "page"]
        lr, pr = class_share_split(len(lines), len(pages), bw, self.line_share)
        rates: Dict[Hashable, float] = {}
        for c in lines:
            rates[c] = lr
        for c in pages:
            rates[c] = pr
        return rates


class SharedHeteroLink(SharedLink):
    """Mixed-arbitration MC link for per-CC heterogeneous policies
    (DESIGN.md §2.9): a flow whose policy partitions the link gets a
    ``(flow, 'line')`` / ``(flow, 'page')`` lane pair; a FIFO flow gets one
    ``(flow, 'all')`` lane that counts as bulk.  When any dual flow has a
    line backlogged AND any bulk lane (a dual flow's pages, or a FIFO
    flow's whole queue) is backlogged, the line class keeps ``line_share``
    of the bandwidth; within a class backlogged lanes share equally.  A
    FIFO flow's lines therefore still serialize behind its own pages (the
    single-flow pathology), while dual flows keep the protected line class
    — per-CC policy choices keep their meaning on a shared fabric.  Only
    instantiated when CC policies actually disagree; homogeneous systems
    keep the legacy Shared{Fifo,DualQueue}Link bit-for-bit."""

    def __init__(self, eng: Engine, bw: float, line_share: float,
                 flow_dual: Sequence[bool],
                 sched: Optional[LinkSchedule] = None):
        self.line_share = line_share
        self.flow_dual = tuple(bool(d) for d in flow_dual)
        channels: List[Hashable] = []
        for f, dual in enumerate(self.flow_dual):
            if dual:
                channels += [(f, "line"), (f, "page")]
            else:
                channels.append((f, "all"))
        super().__init__(eng, bw, channels, sched)

    def _chan(self, flow: int, cls: str) -> Hashable:
        return (flow, cls) if self.flow_dual[flow] else (flow, "all")

    def _split(self, active: List[Hashable], bw: float) -> Dict[Hashable, float]:
        lines = [c for c in active if c[1] == "line"]
        bulk = [c for c in active if c[1] != "line"]
        lr, br = class_share_split(len(lines), len(bulk), bw, self.line_share)
        rates: Dict[Hashable, float] = {}
        for c in lines:
            rates[c] = lr
        for c in bulk:
            rates[c] = br
        return rates


# --------------------------------------------------------------------------
# link factories (shared by the flat model and the fabric ports)
# --------------------------------------------------------------------------


def _arb_maker(eng: Engine, kind: str, share: Optional[float], n_ccs: int,
               flow_dual: Optional[Tuple[bool, ...]] = None):
    """Link factory ``mk(bw, sched)`` for one arbitration kind.  Single-CC
    systems keep the legacy FifoLink/DualQueueLink classes (bit-identical);
    multi-CC systems share the link across per-CC flows."""
    if kind == "hetero":
        return lambda bw, s: SharedHeteroLink(eng, bw, share, flow_dual, s)
    if kind == "dual":
        if n_ccs == 1:
            return lambda bw, s: DualQueueLink(eng, bw, share, s)
        return lambda bw, s: SharedDualQueueLink(eng, bw, share, n_ccs, s)
    if n_ccs == 1:
        return lambda bw, s: FifoLink(eng, bw, s)
    return lambda bw, s: SharedFifoLink(eng, bw, n_ccs, s)


def _downlink_arb(pols, cfg: SimConfig):
    """Downlink arbitration from the CC policies' ``partitioning``
    components: homogeneous fifo/dual (dual flows must also agree on the
    resolved line share), else the per-flow hetero arbitration with the
    line class protected at the strictest (max) share among dual flows.
    Returns ``(kind, share, flow_dual)`` for :func:`_arb_maker`."""
    def share_of(p) -> float:
        return cfg.line_share if p.line_share is None else p.line_share

    parts = {p.partitioning for p in pols}
    shares = {share_of(p) for p in pols}
    if len(parts) == 1 and (parts == {"fifo"} or len(shares) == 1):
        kind = pols[0].partitioning
        return kind, (share_of(pols[0]) if kind == "dual" else None), None
    flow_dual = tuple(p.partitioning == "dual" for p in pols)
    share = max(share_of(p) for p in pols if p.partitioning == "dual")
    return "hetero", share, flow_dual


def _uplink_arb(pols, cfg: SimConfig):
    """Uplink arbitration from the policies' resolved ``uplink`` components
    ('line' class = request packets keeping ``1 - writeback_share``)."""
    req_share = 1.0 - cfg.writeback_share
    parts = {p.uplink_partitioning for p in pols}
    if len(parts) > 1:
        return "hetero", req_share, tuple(
            p.uplink_partitioning == "dual" for p in pols)
    return pols[0].uplink_partitioning, req_share, None


# --------------------------------------------------------------------------
# requests / CC state
# --------------------------------------------------------------------------


@dataclass
class Request:
    addr: int
    t_issue: float
    write: bool
    core: "Core"
    done: bool = False
    t_done: float = 0.0


@dataclass
class Core:
    cid: int
    gaps: np.ndarray
    addrs: np.ndarray
    writes: np.ndarray
    llc: LRU
    idx: int = 0
    t: float = 0.0
    outstanding: deque = field(default_factory=deque)
    stalled: bool = False
    t_end: float = -1.0
    cc: int = 0  # owning compute complex (index into Simulator.ccs)
    # serving layer (§2.9): the core issued its whole phase trace but still
    # has outstanding reads in flight; the last completion re-arms the
    # idle check instead of resuming issue
    draining: bool = False


@dataclass
class CCState:
    """One compute complex (DESIGN.md §2.5): its cores, its local page
    cache of remote memory, its own pending/inflight tracking (DaeMon's
    per-unit engines live per CC), and its own Metrics rollup.  Address
    spaces are per-CC (independent applications); CCs couple only through
    the shared per-MC downlinks."""

    idx: int
    workload: str
    cores: List[Core]
    local: LRU
    m: Metrics
    comp_base: float
    # this CC's MovementPolicy (per-CC heterogeneous systems, §2.9); always
    # set at construction — the same object as Simulator.policy on
    # homogeneous systems, so every dispatch site reads cc.policy
    policy: object = None
    # per-CC compression-ratio RNG: each CC's (de)compression engine samples
    # its own stream, so the draw count of one CC (or scheme) cannot perturb
    # another CC's ratios through global event order
    rng: Optional[np.random.Generator] = None
    # this CC's MovementController (§2.12): the selection/throttle/
    # compression decision state-machine; always set at construction
    # (resolve_controller: policy component > cfg.controller > 'fixed')
    ctrl: object = None
    pending_lines: Dict[int, List[Request]] = field(default_factory=dict)
    pending_pages: Dict[int, List[Request]] = field(default_factory=dict)
    retry: deque = field(default_factory=deque)


class Simulator:
    def __init__(
        self,
        cfg: SimConfig,
        scheme,
        traces,
        workload: str = "",
        seed: int = 0,
        footprints: Optional[Sequence[int]] = None,
    ):
        """``scheme`` is a registered policy name (str), a
        :class:`MovementPolicy` instance (need not be registered), or — for
        per-CC heterogeneous systems (§2.9) — a sequence of either with one
        entry per CC.  ``footprints`` (one per CC) overrides the
        trace-derived footprint; required when a CC starts with empty
        bootstrap traces (the serving layer assigns phases at run time)."""
        self.cfg = cfg
        if isinstance(scheme, (list, tuple)):
            self.policies: Optional[List] = [get_policy(s) for s in scheme]
            if len(self.policies) != max(1, cfg.n_ccs):
                raise ValueError(
                    f"n_ccs={cfg.n_ccs} but {len(self.policies)} per-CC "
                    f"policies given")
            self.policy = self.policies[0]
            names = [p.name for p in self.policies]
            self.scheme = names[0] if len(set(names)) == 1 else "|".join(names)
        else:
            self.policies = None
            self.policy = get_policy(scheme)
            self.scheme = self.policy.name
        self.workload = workload
        self.eng = Engine()
        self.m = Metrics(scheme=self.scheme, workload=workload)
        # memory-side resident state (§2.13): one pool shared by every CC.
        # None (legacy placement, no capacity) keeps the infinite-memory
        # expressions below untouched — committed goldens stay bit-true.
        self.mem = make_memside(cfg.n_mcs, cfg.mc_interleave,
                                cfg.mc_capacity_pages,
                                cfg.mem_hot_threshold, cfg.switch_lat)
        # serving hook (§2.9): called as on_core_idle(core, t) when a core
        # has issued its whole trace and its outstanding reads have drained
        self.on_core_idle: Optional[Callable[[Core, float], None]] = None

        # traces: List[Trace] (legacy, one CC) or List[List[Trace]] (one
        # group per CC).  A Trace is a tuple of ndarrays, so the first
        # element's first element disambiguates the two shapes.
        if traces and isinstance(traces[0][0], np.ndarray):
            cc_traces: List[List[Trace]] = [list(traces)]
        else:
            cc_traces = [list(g) for g in traces]
        if len(cc_traces) != max(1, cfg.n_ccs):
            raise ValueError(
                f"n_ccs={cfg.n_ccs} but {len(cc_traces)} trace group(s) given")
        if footprints is not None and len(footprints) != len(cc_traces):
            raise ValueError(
                f"n_ccs={cfg.n_ccs} but {len(footprints)} footprint(s) given")

        # per-CC workload assignment: 'pr' (all CCs) or a '+'-separated mix
        # ('pr+st') assigned round-robin across CCs
        parts = tuple(workload.split("+")) if workload else ("",)

        llc_lines = cfg.llc_bytes // cfg.line_bytes
        self.lines_per_page = cfg.page_bytes // cfg.line_bytes
        self.ccs: List[CCState] = []
        cid = itertools.count()
        for i, group in enumerate(cc_traces):
            w = parts[i % len(parts)]
            footprint = (int(footprints[i]) if footprints is not None
                         else int(max(int(tr[1].max()) + 64 for tr in group)))
            cores = [
                Core(next(cid), tr[0], tr[1] >> 6, tr[2],
                     LRU(llc_lines // max(1, len(group))), cc=i)
                for tr in group
            ]
            # local memory: page-granularity cache of remote memory
            n_pages_total = footprint // cfg.page_bytes + 1
            local = LRU(max(1, int(n_pages_total * cfg.local_mem_frac)))
            # the single-CC aggregate IS the CC's metrics (legacy identity);
            # multi-CC keeps per-CC metrics and rolls them up in run()
            m = self.m if len(cc_traces) == 1 else Metrics(scheme=self.scheme,
                                                           workload=w)
            # CC 0 keeps the legacy RNG stream (single-CC bit-parity); CC
            # i>0 gets an independent stream keyed by (seed, idx) so ratios
            # are a function of the CC's own draw count only
            pol = self.policies[i] if self.policies else self.policy
            self.ccs.append(CCState(
                idx=i, workload=w, cores=cores, local=local, m=m,
                comp_base=compressibility_of(w if len(parts) > 1 else workload),
                policy=pol,
                rng=(np.random.default_rng(seed + 17) if i == 0
                     else np.random.default_rng((seed + 17, i))),
                ctrl=make_controller(resolve_controller(pol, cfg), cfg,
                                     w if len(parts) > 1 else workload),
            ))
        self.cores = [c for cc in self.ccs for c in cc.cores]
        n_ccs = len(self.ccs)

        # per-MC variability schedules: seeded by (jitter_seed, mc) only, so
        # every scheme sees the same network weather (fair A/B comparison)
        self.scheds = [
            LinkSchedule(cfg.jitter_period, cfg.bw_jitter, cfg.lat_jitter,
                         seed=cfg.jitter_seed * 1000 + i)
            for i in range(cfg.n_mcs)
        ]
        # per-MC links (downlink data path; the request path is folded into
        # net_lat unless cfg.uplink_bw enables the explicit uplink below).
        # Single-CC systems keep the legacy link classes (bit-identical);
        # multi-CC systems share each MC downlink across per-CC flows.  The
        # policy's partitioning component picks the arbitration; when CC
        # policies disagree (heterogeneous partitioning, or dual flows with
        # different line shares) the SharedHeteroLink arbitrates per flow,
        # with the line class protected at the strictest (max) resolved
        # share among the dual flows.
        pols = self.policies if self.policies else [self.policy] * n_ccs
        dkind, dshare, dflow = _downlink_arb(pols, cfg)
        mk = _arb_maker(self.eng, dkind, dshare, n_ccs, dflow)
        # per-MC CC->MC uplinks (§2.7): request packets ('line' class) +
        # writeback bulk ('page' class), arbitrated per the policy's uplink
        # component; both directions see the same per-MC network weather.
        # uplink_bw=None keeps the legacy folded-into-net_lat model
        # bit-for-bit (no up links/ports exist at all).
        mku = None
        if cfg.uplink_bw is not None:
            ukind, ushare, uflow = _uplink_arb(pols, cfg)
            mku = _arb_maker(self.eng, ukind, ushare, n_ccs, uflow)

        if cfg.topology is None:
            # legacy flat model: one private link per MC and direction
            self.fabric = None
            self.links = [mk(cfg.link_bw, s) for s in self.scheds]
            self.uplinks = (None if mku is None else
                            [mku(cfg.uplink_bw, s) for s in self.scheds])
            self._req_hop_lat = [0.0] * cfg.n_mcs
        else:
            # routed fabric (§2.11): transfers cross explicit multi-hop
            # paths.  Endpoint NIC ports keep the policy's endpoint
            # arbitration (so 'direct' is the flat model, bit for bit);
            # switch-owned ports follow the policy 'fabric' component,
            # inheriting the direction's endpoint arbitration when unset —
            # daemon's dual-queue partitioning survives every hop while
            # FIFO baselines stay FIFO end-to-end.
            spec = build_topology(cfg.topology, n_ccs=n_ccs,
                                  n_mcs=cfg.n_mcs, oversub=cfg.oversub)
            fabs = {p.fabric for p in pols}
            fab = fabs.pop() if len(fabs) == 1 else None
            mk_sw = mk if fab is None else _arb_maker(
                self.eng, fab,
                dshare if dshare is not None else cfg.line_share, n_ccs)
            mku_sw = None
            if mku is not None:
                mku_sw = mku if fab is None else _arb_maker(
                    self.eng, fab, ushare, n_ccs)

            def port_link(p: PortSpec):
                bw = (cfg.link_bw if p.down else cfg.uplink_bw) * p.bw_frac
                sched = self.scheds[p.mc] if p.mc is not None else None
                f = ((mk_sw if p.switch else mk) if p.down
                     else (mku_sw if p.switch else mku))
                return f(bw, sched)

            self.fabric = Fabric(self.eng, spec, cfg.switch_lat, port_link,
                                 include_up=mku is not None)
            self.links = [self.fabric.down_route(j)
                          for j in range(cfg.n_mcs)]
            self.uplinks = (None if mku is None else
                            [self.fabric.up_route(j)
                             for j in range(cfg.n_mcs)])
            # folded request path (uplink_bw=None): the request packet
            # still crosses the up path's switches — charge their
            # store-and-forward processing as pure latency (0.0 on 1-hop
            # 'direct' paths, preserving flat-model identity)
            self._req_hop_lat = [float(cfg.switch_lat * self.fabric.up_hops(j))
                                 for j in range(cfg.n_mcs)]

    # ---------------- address helpers ----------------
    def page_of(self, line: int) -> int:
        return line // self.lines_per_page

    def mc_of(self, page: int) -> int:
        """Page -> MC link placement (DESIGN.md §2.3).  A page lives at one
        MC, so its page movement AND the line fetches into it share a link;
        distinct pages spread across independent links per the policy.
        Placement is per-CC-address-space: two CCs' page p land on the same
        MC — they contend for its downlink, not for the page itself.

        This is the legacy static map; with the memory-side state
        subsystem active (§2.13) the transfer paths resolve residency
        through ``self.mem`` instead (``touch`` at issue points,
        ``_mc_peek`` for controller observations)."""
        return mc_place(page, self.cfg.n_mcs, self.cfg.mc_interleave)

    def _mc_peek(self, cc: "CCState", page: int) -> int:
        """Pure resident-MC read for controller observations (§2.12:
        observation paths may be evaluated a different number of times
        per engine, so they must not mutate memside state)."""
        if self.mem is None:
            return self.mc_of(page)
        return self.mem.peek(cc.idx, page)

    def net_lat(self, mc: int, t: float) -> float:
        """One-way network latency on MC link ``mc`` at time ``t``."""
        return self.cfg.net_lat * self.scheds[mc].lat_mult(t)

    def comp_ratio(self, cc: CCState) -> float:
        base = cc.comp_base
        return max(1.0, cc.rng.normal(base, 0.15 * base))

    # ---------------- core execution ----------------
    def start(self):
        for c in self.cores:
            self.eng.at(0.0, lambda t, c=c: self.core_step(c, t))

    def core_step(self, core: Core, t: float):
        cfg = self.cfg
        cc = self.ccs[core.cc]
        core.stalled = False
        t = max(t, core.t)
        n = len(core.addrs)
        while core.idx < n:
            # retire completed requests from the in-order window
            while core.outstanding and core.outstanding[0].done:
                core.outstanding.popleft()
            if len(core.outstanding) >= cfg.mlp:
                core.stalled = True
                core.t = t
                cc.m.stall_episodes += 1  # one per mlp-window fill, not per cycle
                return  # resumed by completion of the oldest request
            line = int(core.addrs[core.idx])
            wr = bool(core.writes[core.idx])
            t += int(core.gaps[core.idx] * cfg.gap_scale)
            core.idx += 1
            cc.m.accesses += 1
            if core.llc.access(line, wr):
                cc.m.llc_hits += 1
                t += cfg.llc_lat
                continue
            t += cfg.llc_lat  # miss detection
            lat = self.miss(cc, core, line, wr, t)
            if lat is not None:  # served synchronously (local memory / 'local')
                t += lat
        core.t = t
        core.t_end = max(core.t_end, t)
        if self.on_core_idle is not None:
            self._maybe_idle(core, t)

    def _maybe_idle(self, core: Core, t: float):
        """Serving hook (§2.9): fire ``on_core_idle`` once per phase, after
        the core has issued its whole trace AND its outstanding reads have
        drained.  Write misses do not block idleness (write-release
        semantics: their fills land through the normal arrival paths).
        Safe against stale deferred events — a newly assigned phase resets
        ``idx`` and the guard below skips the fire."""
        if self.on_core_idle is None or core.idx < len(core.addrs):
            return
        while core.outstanding and core.outstanding[0].done:
            core.outstanding.popleft()
        if core.outstanding:
            core.draining = True  # _complete re-arms the check
            return
        core.draining = False
        t = max(t, core.t)
        core.t_end = max(core.t_end, t)
        self.on_core_idle(core, t)

    def _complete(self, req: Request, t: float):
        req.done = True
        req.t_done = t
        self.ccs[req.core.cc].m.miss_latency_sum += t - req.t_issue
        core = req.core
        if core.stalled and core.outstanding and core.outstanding[0].done:
            self.eng.at(t, lambda tt, c=core: self.core_step(c, tt))
        elif core.draining:
            core.draining = False
            self.eng.at(t, lambda tt, c=core: self._maybe_idle(c, tt))

    def _fill_line(self, core: Core, line: int, dirty: bool):
        core.llc.insert(line, dirty)

    def _insert_page(self, cc: CCState, page: int, t: float):
        ev = cc.local.insert(page)
        if ev is not None and ev[1]:  # dirty eviction -> writeback
            self._send_writeback(cc, ev[0], t)

    # ---------------- miss handling per policy ----------------
    def _local_hit(self, cc: CCState, core: Core, line: int, wr: bool, t: float) -> None:
        """DRAM access in local memory: async within the MLP window."""
        cc.m.local_hits += 1
        self._fill_line(core, line, wr)
        req = self._mk_req(core, line, wr, t)
        self.eng.at(t + self.cfg.mem_lat, lambda tt: self._complete(req, tt))

    def miss(self, cc: CCState, core: Core, line: int, wr: bool, t: float) -> Optional[float]:
        """LLC-miss path, dispatched on the policy's *components* (DESIGN.md
        §2.6) — never on policy names, so new registered compositions need
        no edits here.  The policy is the CC's own (per-CC heterogeneous
        systems, §2.9; the shared object on homogeneous ones)."""
        pol = cc.policy
        gran = pol.granularity
        page = self.page_of(line)

        if gran == "none":  # monolithic: every miss is local DRAM
            self._local_hit(cc, core, line, wr, t)
            return None

        if gran == "line":  # line movement only, no local-memory migration
            cc.m.remote_misses += 1
            req = self._mk_req(core, line, wr, t)
            self._fetch_line(cc, line, t, req)
            return None

        # page-moving policies check local memory first
        if cc.local.access(page, wr):
            self._local_hit(cc, core, line, wr, t)
            return None

        cc.m.remote_misses += 1

        if pol.free_transfers:  # idealized locality bound
            self._insert_page(cc, page, t)
            cc.m.pages_moved += 1
            cc.m.local_hits -= 1  # counted as remote, not a local hit
            self._local_hit(cc, core, line, wr, t)
            return None

        if gran == "page":  # requests ride the page migration
            req = self._mk_req(core, line, wr, t)
            if page in cc.pending_pages:
                cc.pending_pages[page].append(req)
            else:
                cc.pending_pages[page] = [req]
                self._send_page(cc, page, t)
            return None

        # 'both' / 'adaptive': decoupled multi-granularity movement
        return self._composed_miss(cc, core, line, wr, t)

    def _mk_req(self, core: Core, line: int, wr: bool, t: float) -> Request:
        req = Request(line, t, wr, core)
        if not wr:
            core.outstanding.append(req)
        return req

    # ---------------- transfers ----------------
    def _request_flight(self, cc: CCState, mc: int, t: float, extra: float,
                        then: Callable[[float], None]):
        """CC->MC request flight: run ``then`` when the request packet has
        reached MC ``mc`` and its DRAM read (+ ``extra``, e.g. compression
        pipeline fill) has completed.

        Legacy (``uplink_bw=None``): a pure latency — ``net_lat`` +
        ``remote_mem_lat`` — exactly the folded request path.  Uplink model
        (§2.7): the ~``header_bytes`` packet first queues on the contended
        CC->MC uplink's protected 'line' class, then flies."""
        cfg = self.cfg
        if self.uplinks is None:
            # _req_hop_lat charges switch store-and-forward on the folded
            # path (§2.11); 0.0 without a topology — adding it is then an
            # exact float identity, keeping the committed goldens bit-true
            self.eng.at(t + self.net_lat(mc, t) + cfg.remote_mem_lat + extra
                        + self._req_hop_lat[mc], then)
            return
        cc.m.uplink_bytes += cfg.header_bytes

        def on_up_done(tt: float):
            self.eng.at(tt + self.net_lat(mc, tt) + cfg.remote_mem_lat + extra,
                        then)

        self.uplinks[mc].send(t, cfg.header_bytes, on_up_done, "line", cc.idx)

    def _fetch_line(self, cc: CCState, line: int, t: float,
                    req: Optional[Request] = None):
        """Line fetch: request flight + MC read + downlink queue + flight."""
        cfg = self.cfg
        lst = cc.pending_lines.get(line)
        if lst is not None:  # coalesce with the inflight fetch
            if req is not None:
                lst.append(req)
            return
        cc.pending_lines[line] = [req] if req is not None else []
        cc.m.lines_moved += 1
        page = self.page_of(line)
        if self.mem is None:
            mc, xl = self.mc_of(page), 0.0
        else:  # §2.13: resolve residency (allocating on first touch); the
            # promotion signal is moot here — line-granularity policies
            # have no local page cache to promote into
            mc, xl, _ = self.mem.touch(cc.idx, page, "line")
        link = self.links[mc]
        size = cfg.line_bytes + cfg.header_bytes

        def on_tx_done(tt: float):
            arrive = tt + self.net_lat(mc, tt)
            self.eng.at(arrive, lambda a: self._on_line_arrival(cc, line, a))

        self._request_flight(
            cc, mc, t, xl,
            lambda tt: link.send(tt, size, on_tx_done, "line", cc.idx))
        cc.m.net_bytes += size

    def _send_page(self, cc: CCState, page: int, t: float):
        """Demand page migration MC->CC: request flight + MC read +
        downlink queue + flight (+ compression pipeline at either end)."""
        cfg = self.cfg
        if self.mem is None:
            mc, xl = self.mc_of(page), 0.0
        else:  # 'page' touch also resets the hotness count (§2.13): the
            # migration satisfies whatever promotion the tracker wanted
            mc, xl, _ = self.mem.touch(cc.idx, page, "page")
        link = self.links[mc]
        raw = cfg.page_bytes + cfg.header_bytes
        size = raw
        extra = 0.0
        # Link compression (paper §3-III): engaged when the controller
        # signals congestion (for 'fixed', the inflight page buffer past
        # PAGE_FAST — the bandwidth-bound regime).  The compressor is
        # streaming, so only the pipeline fill (~1/4 of the full pass)
        # sits on the critical path; the rest overlaps transmission.
        if (cc.policy.compression != "off" and cfg.compress
                and cc.ctrl.decide(self._obs(cc, mc, t)).compress):
            ratio = self.comp_ratio(cc)
            size = cfg.page_bytes / ratio + cfg.header_bytes
            extra = cfg.comp_lat / 4
            cc.m.bytes_saved_compression += raw - size
        cc.m.net_bytes += size
        cc.m.pages_moved += 1

        def on_tx_done(tt: float):
            arrive = tt + self.net_lat(mc, tt) + (cfg.decomp_lat / 4 if extra else 0.0)
            self.eng.at(arrive, lambda a: self._on_page_arrival(cc, page, a))

        # xl charges the spilled-resident detour (§2.13) on the request
        # path; decompression above stays keyed on `extra` alone
        self._request_flight(
            cc, mc, t, extra + xl,
            lambda tt: link.send(tt, size, on_tx_done, "page", cc.idx))

    def _send_writeback(self, cc: CCState, page: int, t: float):
        """Dirty-page eviction written back CC->MC.

        Legacy (``uplink_bw=None``): the reverse path is not modeled, so the
        writeback is injected into the *downlink* queue (stealing bandwidth
        from demand traffic) and counted as downlink bytes — preserved
        bit-for-bit for golden parity.  Uplink model (§2.7): the writeback
        queues on the CC->MC uplink's bulk 'page' class, counted as uplink
        bytes, and CC-side writeback compression keys off the *uplink
        backlog* (the congestion it actually contends with) instead of the
        downlink inflight-page-buffer signal."""
        cfg = self.cfg
        if self.mem is None:
            mc, xl = self.mc_of(page), 0.0
        else:  # 'wb' touch re-allocates a backing page the pool evicted
            mc, xl, _ = self.mem.touch(cc.idx, page, "wb")
        raw = cfg.page_bytes + cfg.header_bytes
        size = raw
        extra = 0.0
        cc.m.writebacks += 1
        compress = cc.policy.compression != "off" and cfg.compress
        if self.uplinks is None:
            link = self.links[mc]
            if compress and cc.ctrl.decide(self._obs(cc, mc, t)).compress:
                ratio = self.comp_ratio(cc)
                size = cfg.page_bytes / ratio + cfg.header_bytes
                extra = cfg.comp_lat / 4
                cc.m.bytes_saved_compression += raw - size
            cc.m.net_bytes += size
            # compressed at the CC, then "sent back" on the downlink; xl
            # charges the spilled-resident detour (§2.13)
            depart = t + extra + xl
            self.eng.at(depart,
                        lambda tt: link.send(tt, size, lambda a: None, "page", cc.idx))
            return
        up = self.uplinks[mc]
        lu, pu = self._buf_utils(cc)
        if compress and cc.ctrl.decide(
                Observation(t, lu, pu, up.backlog(t))).compress_writeback:
            ratio = self.comp_ratio(cc)
            size = cfg.page_bytes / ratio + cfg.header_bytes
            extra = cfg.comp_lat / 4
            cc.m.bytes_saved_compression += raw - size
        cc.m.uplink_bytes += size
        self.eng.at(t + extra + xl,
                    lambda tt: up.send(tt, size, lambda a: None, "page", cc.idx))

    # ---------------- arrivals ----------------
    def _on_line_arrival(self, cc: CCState, line: int, t: float):
        cc.ctrl.observe_line(t)
        reqs = cc.pending_lines.pop(line, [])
        for r in reqs:
            if not r.done:
                self._fill_line(r.core, line, r.write)
                self._complete(r, t)
        self._drain_retry(cc, t)

    def _on_page_arrival(self, cc: CCState, page: int, t: float):
        cc.ctrl.observe_page(t)
        self._insert_page(cc, page, t)
        reqs = cc.pending_pages.pop(page, [])
        for r in reqs:
            if not r.done:
                self._fill_line(r.core, r.addr, r.write)
                self._complete(r, t + self.cfg.mem_lat)  # read from local memory
        self._drain_retry(cc, t)

    # ---------------- decoupled multi-granularity movement ----------------
    def _buf_utils(self, cc: CCState) -> Tuple[float, float]:
        lu = len(cc.pending_lines) / self.cfg.inflight_lines
        pu = len(cc.pending_pages) / self.cfg.inflight_pages
        return lu, pu

    def _obs(self, cc: CCState, mc: int, t: float) -> Observation:
        """The controller's observation vector at a decision point.  The
        uplink backlog (toward MC ``mc``) is computed only for controllers
        that declare ``needs_uplink`` — a link-heap scan stays off the
        miss hot path under the default 'fixed' controller."""
        lu, pu = self._buf_utils(cc)
        ub = 0.0
        if cc.ctrl.needs_uplink and self.uplinks is not None:
            ub = self.uplinks[mc].backlog(t)
        return Observation(t, lu, pu, ub)

    def _composed_miss(self, cc: CCState, core: Core, line: int, wr: bool,
                       t: float) -> Optional[float]:
        """'both'/'adaptive' granularity: issue line and page movements for a
        triggering miss; requests complete on whichever arrives first.

        The per-CC MovementController (§2.12) makes the decisions.  With
        ``granularity='adaptive'`` its selection unit (paper §3-II)
        modulates racing from the observation vector: under 'fixed', when
        the page buffer drains fast (compressed pages, page-friendly
        phase) redundant line races on coalesced misses are skipped; when
        it backs up (low locality), coalesced misses race lines on the
        critical path.  With ``throttle`` the controller gates issue
        (under 'fixed': pages stop above ``page_throttle_hi``; full
        buffers park the request in the retry queue).
        ``page_carries_requests=False`` is the legacy 'both' race: the
        line always carries the request, the page is pure prefetch."""
        pol = cc.policy
        adaptive = pol.granularity == "adaptive"
        page = self.page_of(line)
        req = self._mk_req(core, line, wr, t)
        coalesced = page in cc.pending_pages
        cc.ctrl.observe_miss(coalesced)
        d = cc.ctrl.decide(self._obs(cc, self._mc_peek(cc, page), t))

        # coalesce with an inflight page migration (the page is already
        # moving, so the line fetch's promotion signal is moot)
        if coalesced:
            if pol.page_carries_requests:
                cc.pending_pages[page].append(req)
            if line in cc.pending_lines:
                cc.pending_lines[line].append(req)
            elif adaptive:
                if d.race_line:
                    cc.pending_lines[line] = [req]
                    self._fetch_line_daemon(cc, line, t, req)
            elif not pol.page_carries_requests:
                cc.pending_lines[line] = [req]
                self._fetch_line_daemon(cc, line, t, req)
            return None

        # triggering miss: BOTH by default — the line hides page queueing and
        # (de)compression latency, costing only ~80B next to a ~2KB page
        if pol.throttle:
            issue_page = d.issue_page
            issue_line = d.issue_line or line in cc.pending_lines
            if not issue_line and not issue_page:
                cc.retry.append(req)  # buffers full: re-issue when one drains
                return None
        else:
            issue_page = issue_line = True

        promote = False
        if issue_line:
            if line in cc.pending_lines:
                cc.pending_lines[line].append(req)
            else:
                cc.pending_lines[line] = [req]
                promote = self._fetch_line_daemon(cc, line, t, req)
        if issue_page:
            waiting = cc.pending_pages.setdefault(page, [])
            if pol.page_carries_requests:
                waiting.append(req)
            self._send_page(cc, page, t)
        # after the issue_page block: a demand migration just issued for
        # this page makes the promotion redundant (guarded inside)
        if promote:
            self._maybe_promote(cc, page, t)
        return None

    def _fetch_line_daemon(self, cc: CCState, line: int, t: float,
                           req: Request) -> bool:
        """Returns the hot-page promotion signal (§2.13) so callers can
        act on it *after* their page-issue bookkeeping settles."""
        cfg = self.cfg
        cc.m.lines_moved += 1
        page = self.page_of(line)
        if self.mem is None:
            mc, xl, promote = self.mc_of(page), 0.0, False
        else:
            mc, xl, promote = self.mem.touch(cc.idx, page, "line")
        link = self.links[mc]
        size = cfg.line_bytes + cfg.header_bytes
        cc.m.net_bytes += size

        def on_tx_done(tt: float):
            arrive = tt + self.net_lat(mc, tt)
            self.eng.at(arrive, lambda a: self._on_line_arrival(cc, line, a))

        self._request_flight(
            cc, mc, t, xl,
            lambda tt: link.send(tt, size, on_tx_done, "line", cc.idx))
        return promote

    def _maybe_promote(self, cc: CCState, page: int, t: float):
        """Hot-page promotion (§2.13): the access-frequency tracker says
        this still-remote page keeps absorbing line fetches — migrate it
        toward the owning CC's page cache, waiterless (later misses
        coalesce onto the inflight entry; the insert's dirty eviction
        rides the normal writeback path).  Throttled by the controller's
        backlog signal: the same inflight-page-buffer utilization the
        Observation carries, bounded at full (pu < 1.0) rather than at
        ``page_throttle_hi`` — hotness accumulates precisely in the
        throttled regime where demand migration stopped, so promotion
        runs there and only yields when the buffer is truly full."""
        if page in cc.pending_pages or page in cc.local:
            return
        if len(cc.pending_pages) >= self.cfg.inflight_pages:
            return
        self.mem.promotions += 1
        cc.pending_pages[page] = []
        self._send_page(cc, page, t)

    def _drain_retry(self, cc: CCState, t: float):
        n = len(cc.retry)
        for _ in range(n):
            req = cc.retry.popleft()
            if req.done:
                continue
            line = req.addr
            page = self.page_of(line)
            d = cc.ctrl.decide(self._obs(cc, self._mc_peek(cc, page), t))
            if line in cc.pending_lines:
                cc.pending_lines[line].append(req)
            elif page in cc.pending_pages:
                cc.pending_pages[page].append(req)
            elif d.issue_line:
                cc.pending_lines[line] = [req]
                if self._fetch_line_daemon(cc, line, t, req):
                    self._maybe_promote(cc, page, t)
            elif d.issue_page:
                cc.pending_pages[page] = [req]
                self._send_page(cc, page, t)
            else:
                cc.retry.append(req)

    # ---------------- run ----------------
    def run(self, until: Optional[float] = None) -> Metrics:
        self.start()
        self.eng.run(until=until)
        for cc in self.ccs:
            cc.m.cycles = max(c.t_end for c in cc.cores)
        if len(self.ccs) == 1:
            self._memside_rollup(self.m)
            return self.m  # the aggregate IS the single CC's metrics
        # aggregate rollup (§2.5): counters sum in CC order, end-to-end
        # cycles is the makespan, and per_cc keeps the full per-CC split
        m = self.m
        for cc in self.ccs:
            m.accesses += cc.m.accesses
            m.llc_hits += cc.m.llc_hits
            m.local_hits += cc.m.local_hits
            m.remote_misses += cc.m.remote_misses
            m.miss_latency_sum += cc.m.miss_latency_sum
            m.net_bytes += cc.m.net_bytes
            m.uplink_bytes += cc.m.uplink_bytes
            m.pages_moved += cc.m.pages_moved
            m.lines_moved += cc.m.lines_moved
            m.writebacks += cc.m.writebacks
            m.bytes_saved_compression += cc.m.bytes_saved_compression
            m.stall_episodes += cc.m.stall_episodes
            d = cc.m.as_dict()
            d.pop("per_cc")
            d["cc"] = cc.idx
            m.per_cc.append(d)
        m.cycles = max(cc.m.cycles for cc in self.ccs)
        self._memside_rollup(m)
        return m

    def _memside_rollup(self, m: Metrics):
        """Copy the cell-global §2.13 pool counters into the aggregate
        (the pool is shared across CCs — per_cc entries keep zeros)."""
        if self.mem is not None:
            m.mc_spills = self.mem.spills
            m.mc_evictions = self.mem.evictions
            m.mc_promotions = self.mem.promotions


def simulate(
    cfg: SimConfig, scheme, traces, workload: str = "", seed: int = 0
) -> Metrics:
    """Run one simulation.  ``scheme`` is a registered policy name or a
    :class:`MovementPolicy`; ``traces`` is a flat ``List[Trace]`` for the
    single-CC model or a ``List[List[Trace]]`` with one group per CC
    (``len == cfg.n_ccs``); ``workload`` may be a '+'-separated mix assigned
    round-robin across CCs."""
    return Simulator(cfg, scheme, traces, workload, seed).run()
