"""Declarative parallel sweep engine for the DS simulator (DESIGN.md §6).

The paper's headline results are grids — schemes x workloads x network
configurations — and its core claim is robustness *across* those axes.  This
module turns every such grid into one declarative :class:`Sweep`:

    sweep = Sweep(
        name="fig2",
        axes={"workload": ("pr", "st"), "scheme": ("page", "daemon"),
              "link_bw_frac": (0.25, 0.125)},
    )
    result = run_sweep(sweep, workers=8)     # process-pool fan-out
    result.save_json("fig2.json")            # standalone artifact
    write_bench("BENCH_sim.json", result)    # merge into the bench ledger

Axis names are ``scheme`` / ``workload`` / ``seed`` / ``n_jobs`` plus any
:class:`SimConfig` field (``link_bw_frac``, ``n_mcs``, ``bw_jitter``, ...).
Cells are the cartesian product in declaration order.  Each cell is an
independent simulation with deterministic seeding (a pure function of the
cell's axis values), so a parallel run is cell-for-cell identical to a
serial run of the same sweep — verified by tests/test_sweep.py.
"""
from __future__ import annotations

import itertools
import json
import os
import time
import zlib
from concurrent.futures import ProcessPoolExecutor
from dataclasses import asdict, dataclass
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.core.sim.config import Metrics, SimConfig
from repro.core.sim.controller import get_controller
from repro.core.sim.engine import simulate
from repro.core.sim.engine_batch import BatchCell, covers, run_batch
from repro.core.sim.memside import get_placement
from repro.core.sim.policy import MovementPolicy, get_policy
from repro.core.sim.serving import get_router, serve_one
from repro.core.sim.trace import generate, get_workload

BENCH_SCHEMA = "repro.sim.sweep/v1"

# cell execution engines: "python" is the per-cell oracle event loop,
# "batch" the lockstep struct-of-arrays core (engine_batch.py) with
# automatic per-cell fallback to the oracle for uncovered configs
ENGINES = ("python", "batch")

# axes consumed by the cell runner itself; everything else must be a
# SimConfig field and is applied with cfg.with_()
RESERVED_AXES = ("scheme", "workload", "seed", "n_jobs")


# --------------------------------------------------------------------------
# cell primitive
# --------------------------------------------------------------------------


def run_one(
    workload: str,
    scheme,
    cfg: Optional[SimConfig] = None,
    *,
    seed: int = 0,
    n_accesses: int = 60_000,
    footprint: int = 16 << 20,
    n_jobs: int = 1,
) -> Metrics:
    """One application = cfg.n_cores threads of the workload (multicore CC);
    n_jobs > 1 stacks additional independent applications on the same CC.
    ``scheme`` is a registered policy name or a
    :class:`~repro.core.sim.policy.MovementPolicy` instance; ``workload``
    names registered trace sources (unknown names fail fast listing the
    registered choices).

    With ``cfg.n_ccs > 1`` every CC runs its own full application
    (``n_accesses`` is per CC, so aggregate traffic scales with the CC
    count — the contention the multi-CC model measures).  ``workload`` may
    be a '+'-separated mix ('pr+st'): CC ``c`` runs ``parts[c % len(parts)]``,
    so with fewer CCs than parts the tail parts do NOT run (a 4-part mix at
    n_ccs=1 is a pure parts[0] run) and the workload composition of a mix
    varies with n_ccs.  Scheme comparisons at a fixed (mix, n_ccs) cell are
    always composition-matched; trend reads *across* n_ccs are
    composition-stable only for mixes whose length divides every compared
    CC count (e.g. a single workload).  CC 0's trace seeds match the
    single-CC model exactly."""
    cfg = cfg or SimConfig()
    scheme = get_policy(scheme)  # fail fast on unknown policy names
    if cfg.serving_router is not None:
        # open-loop serving cell (DESIGN.md §2.9): the request layer builds
        # its own phase traces from cfg.{prefill,decode}_* — ``workload``,
        # ``n_accesses``, ``footprint`` and ``n_jobs`` do not apply
        return serve_one(cfg, scheme, seed=seed)
    n_ccs = max(1, cfg.n_ccs)
    parts = tuple(workload.split("+")) if workload else (workload,)
    for p in parts:  # fail fast on unknown workload names
        get_workload(p)
    n_threads = max(1, cfg.n_cores) * max(1, n_jobs)
    per = max(1, n_accesses // n_threads)
    if n_ccs == 1 and len(parts) == 1:
        traces = [generate(workload, seed=seed + j, footprint=footprint, n=per)
                  for j in range(n_threads)]
        return simulate(cfg, scheme, traces, workload=workload, seed=seed)
    cc_traces = [
        [generate(parts[c % len(parts)], seed=seed + c * n_threads + j,
                  footprint=footprint, n=per)
         for j in range(n_threads)]
        for c in range(n_ccs)
    ]
    return simulate(cfg, scheme, cc_traces, workload=workload, seed=seed)


# --------------------------------------------------------------------------
# sweep spec
# --------------------------------------------------------------------------


def cell_seed(axes: Mapping[str, Any], base_seed: int = 0) -> int:
    """Deterministic per-cell seed: a pure function of the cell's axis values
    (stable across processes, Python versions, and execution order)."""
    blob = json.dumps({k: axes[k] for k in sorted(axes)}, sort_keys=True,
                      default=str).encode()
    return (base_seed + zlib.crc32(blob)) % (1 << 31)


@dataclass(frozen=True)
class Sweep:
    """Declarative grid of simulator cells (cartesian product of ``axes``).

    ``derive_seeds=False`` (default) runs every cell at ``base_seed`` (or the
    explicit ``seed`` axis) — required when cells are later compared ratio-
    style against each other on identical traces.  ``derive_seeds=True``
    mixes a hash of the cell's axes — excluding ``scheme``, which never
    influences the trace — into the seed, so cells draw decorrelated traces
    across seeds/workloads/configs while cells differing only in scheme
    still run the SAME traces: variance studies keep scheme-ratio
    comparisons trace-paired."""

    name: str
    axes: Mapping[str, Sequence[Any]]
    base: SimConfig = SimConfig()
    n_accesses: int = 60_000  # matches run_one's default
    footprint: int = 16 << 20
    base_seed: int = 0
    derive_seeds: bool = False
    engine: str = "python"  # see ENGINES; overridable per run_sweep call

    def __post_init__(self):
        if self.engine not in ENGINES:
            raise ValueError(
                f"unknown engine {self.engine!r}; choose one of {ENGINES}")
        for k, v in self.axes.items():
            if k not in RESERVED_AXES and k not in SimConfig.__dataclass_fields__:
                raise ValueError(f"unknown sweep axis {k!r}")
            if isinstance(v, (str, bytes)):
                raise ValueError(
                    f"axis {k!r} must be a sequence of values, not {v!r} "
                    f"(did you mean ({v!r},)?)")
        # fail fast on unknown policy/workload names (registry lookups list
        # the available choices), at declaration time rather than mid-sweep
        for s in self.axes.get("scheme", ()):
            if isinstance(s, MovementPolicy):
                raise ValueError(
                    f"scheme axis values must be registered policy names; "
                    f"register_policy({s.name!r}) first")
            get_policy(s)
        for mix in self.axes.get("workload", ()):
            for part in mix.split("+"):
                get_workload(part)
        for r in self.axes.get("serving_router", ()):
            if r is not None:
                get_router(r)
        for ax in ("controller", "serving_prefill_controller",
                   "serving_decode_controller"):
            for c in self.axes.get(ax, ()):
                if c is not None:
                    get_controller(c)
        for p in self.axes.get("mc_interleave", ()):
            get_placement(p)
        object.__setattr__(self, "axes", {k: tuple(v) for k, v in self.axes.items()})

    def cells(self) -> List[Dict[str, Any]]:
        keys = list(self.axes)
        return [dict(zip(keys, combo))
                for combo in itertools.product(*(self.axes[k] for k in keys))]

    def __len__(self) -> int:
        n = 1
        for v in self.axes.values():
            n *= len(v)
        return n


@dataclass
class CellResult:
    axes: Dict[str, Any]
    metrics: Metrics
    seed: int
    cpu_s: float = 0.0  # this cell's own CPU time, measured inside the worker

    def as_dict(self) -> dict:
        return {"axes": self.axes, "seed": self.seed, "cpu_s": self.cpu_s,
                "metrics": self.metrics.as_dict()}

    @classmethod
    def from_dict(cls, d: dict) -> "CellResult":
        return cls(axes=dict(d["axes"]), seed=int(d.get("seed", 0)),
                   cpu_s=float(d.get("cpu_s", 0.0)),
                   metrics=Metrics.from_dict(d["metrics"]))


def _resolve_cell(sweep: Sweep, cell: Dict[str, Any]) -> Tuple[SimConfig, int]:
    """Cell axes -> (SimConfig, seed): the single definition both engines
    share, so the batch path cannot drift from the oracle path."""
    cfg_kw = {k: v for k, v in cell.items() if k not in RESERVED_AXES}
    cfg = sweep.base.with_(**cfg_kw) if cfg_kw else sweep.base
    seed = int(cell.get("seed", sweep.base_seed))
    if sweep.derive_seeds:
        # exclude 'scheme': it never influences trace generation, and
        # hashing it would unpair the traces that scheme-ratio comparisons
        # (scheme_ratio/scheme_geomean) divide against each other
        seed = cell_seed({k: v for k, v in cell.items() if k != "scheme"},
                         base_seed=seed)
    return cfg, seed


def _to_batch_cell(sweep: Sweep, cell: Dict[str, Any]) -> BatchCell:
    cfg, seed = _resolve_cell(sweep, cell)
    return BatchCell(cell.get("workload", "pr"), cell.get("scheme", "daemon"),
                     cfg, seed=seed, n_accesses=sweep.n_accesses,
                     footprint=sweep.footprint,
                     n_jobs=int(cell.get("n_jobs", 1)))


def _run_cell(payload: Tuple[Sweep, Dict[str, Any]]) -> CellResult:
    """Top-level (picklable) worker: execute one sweep cell on the oracle."""
    sweep, cell = payload
    cfg, seed = _resolve_cell(sweep, cell)
    t0 = time.process_time()  # CPU time: robust to pool oversubscription
    m = run_one(
        cell.get("workload", "pr"),
        cell.get("scheme", "daemon"),
        cfg,
        seed=seed,
        n_accesses=sweep.n_accesses,
        footprint=sweep.footprint,
        n_jobs=int(cell.get("n_jobs", 1)),
    )
    return CellResult(axes=cell, metrics=m, seed=seed,
                      cpu_s=time.process_time() - t0)


def _run_batch_group(
    payload: Tuple[Sweep, List[Tuple[int, Dict[str, Any]]]],
) -> List[Tuple[int, CellResult]]:
    """Top-level (picklable) worker: run a group of covered cells through the
    batch engine in one lockstep pass, returning (row_index, CellResult)
    pairs.  Per-cell cpu_s is measured inside the batch driver."""
    sweep, idx_cells = payload
    bcells = [_to_batch_cell(sweep, cell) for _, cell in idx_cells]
    br = run_batch(bcells)
    return [
        (i, CellResult(axes=cell, metrics=m, seed=bc.seed, cpu_s=cpu))
        for (i, cell), bc, m, cpu in zip(idx_cells, bcells, br.metrics,
                                         br.cpu_s)
    ]


def _trace_signature(bc: BatchCell) -> tuple:
    """Trace-shape signature: cells with equal signatures replay the same
    prepared traces, so they belong in the same worker's TracePool."""
    cfg = bc.cfg
    return (bc.workload, bc.seed, bc.footprint, bc.n_accesses, bc.n_jobs,
            max(1, cfg.n_cores), max(1, cfg.n_ccs), cfg.gap_scale)


# --------------------------------------------------------------------------
# execution
# --------------------------------------------------------------------------


@dataclass
class SweepResult:
    name: str
    axes: Dict[str, tuple]
    rows: List[CellResult]
    wall_s: float = 0.0
    workers: int = 1
    engine: str = "python"  # which cell engine produced the rows
    # provenance: the Sweep spec that produced the rows (base SimConfig,
    # n_accesses, footprint, seed policy) so ledger entries are reproducible
    spec: Optional[Dict[str, Any]] = None

    def __iter__(self):
        return iter(self.rows)

    def __len__(self):
        return len(self.rows)

    @property
    def us_per_call(self) -> float:
        """Mean per-cell CPU time in µs, measured inside each worker — i.e.
        simulation cost, independent of how many workers ran the sweep or how
        oversubscribed they were (``wall_s`` is the elapsed wall-clock of the
        whole sweep)."""
        if not self.rows:
            return 0.0
        return sum(r.cpu_s for r in self.rows) * 1e6 / len(self.rows)

    def filter(self, **axes) -> List[CellResult]:
        return [r for r in self.rows
                if all(r.axes.get(k) == v for k, v in axes.items())]

    def grid(self, *keys: str) -> Dict[tuple, CellResult]:
        """Index rows by a tuple of axis values, e.g. grid('workload','scheme')."""
        return {tuple(r.axes[k] for k in keys): r for r in self.rows}

    # -------- persistence (docs/SWEEPS.md describes the schema) --------
    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "axes": {k: list(v) for k, v in self.axes.items()},
            "spec": self.spec,
            "wall_s": self.wall_s,
            "workers": self.workers,
            "engine": self.engine,
            "n_cells": len(self.rows),
            "rows": [r.as_dict() for r in self.rows],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "SweepResult":
        return cls(
            name=d["name"],
            axes={k: tuple(v) for k, v in d["axes"].items()},
            rows=[CellResult.from_dict(r) for r in d["rows"]],
            wall_s=float(d.get("wall_s", 0.0)),
            workers=int(d.get("workers", 1)),
            engine=str(d.get("engine", "python")),
            spec=d.get("spec"),
        )

    def save_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=1, sort_keys=True)

    @classmethod
    def load_json(cls, path: str) -> "SweepResult":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def default_workers() -> int:
    """Worker count: REPRO_SWEEP_WORKERS env override, else the cores this
    process may actually run on (cgroup/affinity-aware where available)."""
    env = os.environ.get("REPRO_SWEEP_WORKERS")
    if env:
        return max(1, int(env))
    try:
        return len(os.sched_getaffinity(0)) or 1
    except AttributeError:  # non-Linux
        return os.cpu_count() or 1


def _run_cells_batch(sweep: Sweep, cells: List[Dict[str, Any]],
                     workers: int) -> List[CellResult]:
    """Batch-engine execution plan: covered cells advance in lockstep
    (grouped so cells sharing a trace-shape signature land in the same
    worker's TracePool), uncovered cells fall back to the oracle cell
    runner.  Row order matches ``cells`` and results are bit-identical to
    the python engine regardless of ``workers``."""
    covered: List[Tuple[int, Dict[str, Any]]] = []
    fallback: List[Tuple[int, Dict[str, Any]]] = []
    sigs: Dict[int, tuple] = {}
    for i, cell in enumerate(cells):
        bc = _to_batch_cell(sweep, cell)
        if covers(bc.cfg, bc.scheme):
            covered.append((i, cell))
            sigs[i] = _trace_signature(bc)
        else:
            fallback.append((i, cell))
    rows: List[Optional[CellResult]] = [None] * len(cells)
    if workers == 1:
        for i, res in _run_batch_group((sweep, covered)):
            rows[i] = res
        for i, cell in fallback:
            rows[i] = _run_cell((sweep, cell))
        return rows
    # parallel: one batch group per worker, filled signature-by-signature
    # (largest first, into the least-loaded bucket) so trace sharing stays
    # intra-worker while the cell count stays balanced
    groups: Dict[tuple, List[Tuple[int, Dict[str, Any]]]] = {}
    for i, cell in covered:
        groups.setdefault(sigs[i], []).append((i, cell))
    n_buckets = min(workers, len(groups)) or 1
    buckets: List[List[Tuple[int, Dict[str, Any]]]] = [[] for _ in
                                                       range(n_buckets)]
    sizes = [0] * n_buckets
    for g in sorted(groups.values(), key=len, reverse=True):
        j = sizes.index(min(sizes))
        buckets[j].extend(g)
        sizes[j] += len(g)
    with ProcessPoolExecutor(max_workers=workers) as pool:
        futs = [pool.submit(_run_batch_group, (sweep, b))
                for b in buckets if b]
        fb = pool.map(_run_cell, [(sweep, c) for _, c in fallback],
                      chunksize=1)
        for fut in futs:
            for i, res in fut.result():
                rows[i] = res
        for (i, _), res in zip(fallback, fb):
            rows[i] = res
    return rows


def run_sweep(sweep: Sweep, workers: Optional[int] = None,
              engine: Optional[str] = None) -> SweepResult:
    """Execute every cell of ``sweep``; ``workers<=1`` runs serial in-process,
    otherwise cells fan out over a process pool.  ``engine`` overrides
    ``sweep.engine`` ("python" = per-cell oracle, "batch" = lockstep batch
    core with oracle fallback for uncovered cells).  Row order always
    matches ``sweep.cells()`` and per-cell results are independent of both
    ``workers`` and ``engine``."""
    cells = sweep.cells()
    eng = sweep.engine if engine is None else engine
    if eng not in ENGINES:
        raise ValueError(f"unknown engine {eng!r}; choose one of {ENGINES}")
    t0 = time.perf_counter()
    if workers is None:
        workers = 1
    workers = max(1, min(workers, len(cells) or 1))
    if eng == "batch":
        rows = _run_cells_batch(sweep, cells, workers)
    elif workers == 1:
        rows = [_run_cell((sweep, c)) for c in cells]
    else:
        # chunksize=1: cell costs vary by >10x across schemes/bandwidths, so
        # dynamic single-cell dispatch beats static chunking; IPC cost per
        # cell (~ms) is noise next to a cell (~100ms+)
        with ProcessPoolExecutor(max_workers=workers) as pool:
            rows = list(pool.map(_run_cell, [(sweep, c) for c in cells],
                                 chunksize=1))
    spec = {
        "base": asdict(sweep.base),
        "n_accesses": sweep.n_accesses,
        "footprint": sweep.footprint,
        "base_seed": sweep.base_seed,
        "derive_seeds": sweep.derive_seeds,
    }
    return SweepResult(name=sweep.name, axes=dict(sweep.axes), rows=rows,
                       wall_s=time.perf_counter() - t0, workers=workers,
                       engine=eng, spec=spec)


# --------------------------------------------------------------------------
# derived statistics
# --------------------------------------------------------------------------


def geomean(xs: Iterable[float]) -> float:
    import math

    xs = [max(x, 1e-12) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def scheme_ratio(
    rows: Iterable[CellResult],
    num: str = "page",
    den: str = "daemon",
    metric: str = "cycles",
) -> Dict[tuple, float]:
    """Pair cells that differ only in ``scheme`` and return num/den ratios
    keyed by the remaining axis values (>1 means ``den`` wins on cycles)."""
    by_key: Dict[tuple, Dict[str, CellResult]] = {}
    for r in rows:
        key = tuple((k, v) for k, v in sorted(r.axes.items()) if k != "scheme")
        by_key.setdefault(key, {})[r.axes.get("scheme", "")] = r
    out = {}
    for key, pair in by_key.items():
        if num in pair and den in pair:
            a = getattr(pair[num].metrics, metric)
            b = getattr(pair[den].metrics, metric)
            out[key] = a / max(b, 1e-12)
    return out


def scheme_geomean(rows: Iterable[CellResult], num: str = "page",
                   den: str = "daemon", metric: str = "cycles") -> float:
    """Geomean of num/den over all paired cells — the paper's summary stat."""
    ratios = scheme_ratio(rows, num, den, metric)
    return geomean(ratios.values()) if ratios else float("nan")


# --------------------------------------------------------------------------
# BENCH_sim.json ledger
# --------------------------------------------------------------------------


def wall_stats(result: SweepResult) -> Dict[str, float]:
    """Non-gated throughput observability keys (``wall_*`` prefix, skipped
    by check_bench's gate): per-section wall-clock, cells/sec, and mean
    per-cell CPU seconds.  Written into every ledger entry so nightly runs
    can chart engine-throughput trends across commits."""
    n = len(result.rows)
    wall = result.wall_s
    return {
        "wall_s": round(wall, 4),
        "wall_cells_per_s": round(n / wall, 4) if wall > 0 else 0.0,
        "wall_cpu_s_per_cell": round(
            sum(r.cpu_s for r in result.rows) / n, 6) if n else 0.0,
    }


def write_bench(path: str, result: SweepResult,
                derived: Optional[Mapping[str, Any]] = None) -> dict:
    """Merge ``result`` into the BENCH_sim.json ledger at ``path`` (created if
    missing), keyed by sweep name so repeated runs overwrite their own entry.
    ``derived`` attaches summary stats (e.g. daemon-vs-page geomeans); the
    non-gated ``wall_*`` throughput keys are always attached.  The
    read-modify-write holds an advisory lock so concurrently-running
    benchmarks do not drop each other's entries."""
    lock = open(path + ".lock", "w")
    try:
        try:
            import fcntl

            fcntl.flock(lock, fcntl.LOCK_EX)
        except ImportError:  # non-POSIX: single-writer assumption
            pass
        doc: Dict[str, Any] = {"schema": BENCH_SCHEMA, "sweeps": {}}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    prev = json.load(f)
                if isinstance(prev, dict) and prev.get("schema") == BENCH_SCHEMA:
                    doc = prev
            except (json.JSONDecodeError, OSError):
                pass  # corrupt/foreign ledger: rewrite from scratch
        entry = result.as_dict()
        entry["derived"] = {**wall_stats(result), **(dict(derived or {}))}
        doc.setdefault("sweeps", {})[result.name] = entry
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return doc
    finally:
        lock.close()
