"""Batch simulation core: many independent sweep cells advanced in lockstep.

The Python event engine in :mod:`engine` is the *oracle*: every behaviour is
defined there.  This module re-implements the covered subset as a flat,
monomorphized event loop so that one process can advance a whole *batch* of
sweep cells — sharing prepared traces, jitter-schedule caches, and the
interpreter's warm state across cells — at a fraction of the oracle's cost.
It is a transcription, not a reformulation: every arithmetic expression goes
through the same pure helpers (`fifo_finish`, `class_share_split`,
`mc_place`, `selection_races_line` in :mod:`engine`) or
repeats the oracle's float expression shape verbatim, and every event the
oracle enqueues maps 1:1 (same timestamp, same sequence number) to an event
here.  The contract — enforced by tests/test_engine_batch.py — is
**cell-for-cell bit-identical metrics** against the oracle.

Where the speed comes from (DESIGN.md §2.10):

- events are plain tuples ``(t, seq, kind, a, b)`` dispatched by one flat
  loop instead of per-event closures, with the core-step / completion /
  arrival handlers (and their LRU touch points, as raw OrderedDict
  operations) inlined at the dispatch arms;
- the oracle's no-op writeback transmit-completion callback is elided
  instead of enqueued: dropping a push/pop pair whose handler has no
  effect renumbers the remaining sequence numbers monotonically, so every
  relative (t, seq) comparison — hence the pop order — is preserved;
- traces are prepared once per ``(workload, seed, footprint, n, gap_scale)``
  signature — pre-scaled gap lists, pre-shifted line lists — and shared by
  every cell in the batch that replays them (the fig2 grid replays each
  trace once per scheme);
- jitter schedules are shared per ``(period, jitter, seed)`` so the
  per-epoch multiplier cache is computed once for the whole batch;
- link lanes are lists indexed by channel number, not dicts keyed by
  ``(flow, class)`` tuples;
- per-cell cursors/backlogs/counters live in struct-of-arrays numpy views
  (:class:`BatchState`), synced at lockstep-quantum boundaries, so the
  driver can observe and report progress across the batch without touching
  the hot loop.

Coverage: everything :func:`repro.core.sim.sweep.run_one` can express
*except* the request-level serving layer (``cfg.serving_router``),
routed fabric topologies (``cfg.topology``), and per-CC heterogeneous
policy lists.  :func:`covers` is the dispatch predicate; uncovered cells
fall back to the oracle in ``run_sweep``.
"""
from __future__ import annotations

import gc
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from heapq import heappop, heappush
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.sim.config import Metrics, SimConfig
from repro.core.sim.controller import (
    Observation,
    make_controller,
    resolve_controller,
)
from repro.core.sim.engine import (
    PAGE_FAST,
    LinkSchedule,
    class_share_split,
    fifo_finish,
    mc_place,
    selection_races_line,
)
from repro.core.sim.memside import make_memside
from repro.core.sim.policy import MovementPolicy, get_policy
from repro.core.sim.trace import compressibility_of, generate

# event kinds (tuple field 2); dispatch order in _Frame.advance roughly
# tracks frequency on the quick grids
K_CORE = 0       # a = global core index
K_COMPLETE = 1   # a = request record
K_FLIGHT = 2     # a = link, b = (size, clsidx, flow, cbdesc): deferred send
K_TXDONE = 3     # a = cbdesc (FIFO link transmit completion)
K_FIRE = 4       # a = link, b = (channel, epoch) (fluid-link head ETA)
K_LINE_ARR = 5   # a = cc index, b = line
K_PAGE_ARR = 6   # a = cc index, b = page
K_WBSEND = 7     # a = link, b = (size, flow): delayed writeback injection

# request records are plain lists: [addr, t_issue, write, core_k, done]
R_ADDR, R_TISSUE, R_WR, R_CORE, R_DONE = range(5)

# callback descriptors (cbdesc): what the oracle captures in its closures.
# ("line", cc, line, mc) / ("page", cc, page, mc, has_decomp) are the
# downlink on_tx_done callbacks; ("up", mc, extra, link, size, clsidx, cc,
# inner) is the uplink on_up_done; NOP is the oracle's `lambda a: None`
# writeback callback (it still occupies a lane / consumes a seq number).
NOP = ("nop",)

CLS_LINE, CLS_PAGE = 0, 1


def uncovered_reason(cfg: SimConfig, scheme: Any) -> Optional[str]:
    """Why the batch core cannot reproduce this cell bit-for-bit, naming
    the config field responsible (actionable fallback diagnostics), or
    ``None`` when the cell is covered."""
    if isinstance(scheme, (list, tuple)):
        return ("scheme is a per-CC heterogeneous policy list "
                "(SharedHeteroLink arbitration, §2.9)")
    if cfg.serving_router is not None:
        return (f"serving_router={cfg.serving_router!r} enables the "
                f"request-level serving layer (§2.9)")
    if cfg.topology is not None:
        return (f"topology={cfg.topology!r} routes transfers over a "
                f"multi-hop fabric (§2.11)")
    return None


def covers(cfg: SimConfig, scheme: Any) -> bool:
    """True when the batch core reproduces this cell bit-for-bit; False
    routes the cell to the oracle (automatic fallback in run_sweep).
    Memory-side state cells (§2.13: mc_capacity_pages set and/or a
    non-legacy mc_interleave placement) ARE covered — both engines drive
    the same MemsideState at the same event points."""
    return uncovered_reason(cfg, scheme) is None


# --------------------------------------------------------------------------
# shared pools: prepared traces + jitter schedules
# --------------------------------------------------------------------------


class TracePool:
    """Prepared traces shared across a batch, keyed by trace-shape signature
    ``(workload, seed, footprint, n, gap_scale)``.  A prepared trace is
    ``(gaps, lines, writes, raw_max)`` where ``gaps`` is the pre-scaled
    integer gap list (``int(gap * gap_scale)`` elementwise — the oracle's
    per-access expression), ``lines`` the pre-shifted ``addr >> 6`` list,
    and ``raw_max`` the max raw address (the oracle's footprint input)."""

    def __init__(self):
        self._d: Dict[tuple, tuple] = {}

    def get(self, workload: str, seed: int, footprint: int, n: int,
            gap_scale: float) -> tuple:
        key = (workload, seed, footprint, n, gap_scale)
        prep = self._d.get(key)
        if prep is None:
            gaps, addrs, writes = generate(workload, seed=seed,
                                           footprint=footprint, n=n)
            prep = self._d[key] = (
                (gaps * gap_scale).astype(np.int64).tolist(),
                (addrs >> 6).tolist(),
                writes.tolist(),
                int(addrs.max()),
            )
        return prep


class SchedPool:
    """LinkSchedules shared across a batch, keyed by their defining tuple.
    Multipliers are a pure function of (seed, epoch), so sharing the
    schedule object shares its epoch cache — the piecewise jitter
    integration is computed once per epoch for every cell in the batch."""

    def __init__(self):
        self._d: Dict[tuple, LinkSchedule] = {}

    def get(self, period: int, bw_jitter: float, lat_jitter: float,
            seed: int) -> LinkSchedule:
        key = (period, bw_jitter, lat_jitter, seed)
        s = self._d.get(key)
        if s is None:
            s = self._d[key] = LinkSchedule(period, bw_jitter, lat_jitter,
                                            seed=seed)
        return s


# --------------------------------------------------------------------------
# link lanes (monomorphized transcriptions of engine.py's link classes)
# --------------------------------------------------------------------------


class _BFifo:
    """FifoLink: one store-and-forward queue (busy-until scalar)."""

    __slots__ = ("bw", "sched", "busy", "nbytes")

    def __init__(self, bw: float, sched: Optional[LinkSchedule]):
        self.bw = bw
        # an inert schedule (bw_jitter == 0) behaves exactly like None in
        # fifo_finish; dropping it here just skips the property check
        self.sched = sched if (sched is not None and sched.bw_active) else None
        self.busy = 0.0
        self.nbytes = 0.0

    def send(self, fr: "_Frame", t: float, size, cbdesc, clsidx: int,
             flow: int):
        busy = self.busy
        start = t if t > busy else busy  # max(t, busy_until)
        sched = self.sched
        if sched is None:
            done = start + size / self.bw  # fifo_finish's inert-schedule arm
        else:
            done = fifo_finish(start, size, self.bw, sched)
        self.busy = done
        self.nbytes += size
        if cbdesc is not NOP:
            s = fr.seq
            heappush(fr.heap, (done, s, K_TXDONE, cbdesc, 0))
            fr.seq = s + 1
        # NOP (the oracle's `lambda a: None` writeback callback) is elided:
        # its handler has no effect, and dropping a push/pop pair renumbers
        # the remaining sequence numbers monotonically, so every relative
        # (t, seq) comparison — hence every pop order — is preserved.

    def backlog(self, t: float) -> float:
        d = self.busy - t
        return (d if d > 0.0 else 0.0) * self.bw


class _BDual:
    """DualQueueLink: fluid line/page classes, single flow."""

    __slots__ = ("bw", "ls", "ps", "sched", "hl", "hp", "cl", "cp",
                 "ql", "qp", "last", "epoch", "nbytes")

    def __init__(self, bw: float, line_share: float,
                 sched: Optional[LinkSchedule]):
        self.bw = bw
        self.ls = line_share
        self.ps = 1.0 - line_share  # precomputed at init, as the oracle does
        self.sched = sched if (sched is not None and sched.bw_active) else None
        self.hl = 0.0
        self.hp = 0.0
        self.cl: Optional[tuple] = None
        self.cp: Optional[tuple] = None
        self.ql: deque = deque()
        self.qp: deque = deque()
        self.last = 0.0
        self.epoch = 0
        self.nbytes = 0.0

    def _advance(self, t: float):
        hl = self.hl
        hp = self.hp
        if hl <= 0 and hp <= 0:
            if t > self.last:
                self.last = t  # idle link: skip epoch walking
            return
        sched = self.sched
        if sched is None:
            last = self.last
            if last < t:
                dt = t - last
                bw = self.bw
                if hl > 0:
                    r = self.ls * bw if hp > 0 else bw
                    v = hl - r * dt
                    self.hl = v if v > 0.0 else 0.0
                if hp > 0:
                    r = self.ps * bw if hl > 0 else bw
                    v = hp - r * dt
                    self.hp = v if v > 0.0 else 0.0
                self.last = t
            return
        last = self.last
        while last < t:
            nb = sched.next_boundary(last)
            seg = t if t < nb else nb  # min(t, next_boundary)
            dt = seg - last
            if dt > 0:
                bw = self.bw * sched.bw_mult(last)
                hl = self.hl
                hp = self.hp
                if hl > 0:
                    r = self.ls * bw if hp > 0 else bw
                    v = hl - r * dt
                    self.hl = v if v > 0.0 else 0.0
                if hp > 0:
                    r = self.ps * bw if hl > 0 else bw
                    v = hp - r * dt
                    self.hp = v if v > 0.0 else 0.0
            last = seg
        self.last = last

    def _schedule(self, fr: "_Frame", t: float):
        self.epoch += 1
        hl = self.hl
        hp = self.hp
        if hl <= 0 and hp <= 0:
            return
        sched = self.sched
        bw = self.bw * sched.bw_mult(t) if sched is not None else self.bw
        if hl > 0:
            rl = self.ls * bw if hp > 0 else bw
        else:
            rl = 0.0
        if hp > 0:
            rp = self.ps * bw if hl > 0 else bw
        else:
            rp = 0.0
        # candidate order line-then-page with strict < tiebreak, as oracle
        eta = None
        c = CLS_LINE
        if hl > 0 and rl > 0:
            eta = t + hl / rl
        if hp > 0 and rp > 0:
            e2 = t + hp / rp
            if eta is None or e2 < eta:
                eta = e2
                c = CLS_PAGE
        if eta is None:
            return
        if sched is not None:
            nb = sched.next_boundary(t)
            if eta > nb:
                eta = nb  # re-derive rates at the epoch boundary
        s = fr.seq
        heappush(fr.heap, (eta, s, K_FIRE, self, (c, self.epoch)))
        fr.seq = s + 1

    def fire(self, fr: "_Frame", tt: float, c: int, epoch: int):
        if epoch != self.epoch:
            return  # stale
        self._advance(tt)
        # epsilon in *bytes*, exactly the oracle's storm guard
        if (self.hl if c == CLS_LINE else self.hp) > 1e-3:
            self._schedule(fr, tt)
            return
        if c == CLS_LINE:
            cb = self.cl
            self._pop_l()
        else:
            cb = self.cp
            self._pop_p()
        self._schedule(fr, tt)
        if cb is not None:
            fr._run_cb(cb, tt)  # NOP lane heads fall through (no arrival)

    def _pop_l(self):
        q = self.ql
        if q:
            size, cb = q.popleft()
            self.hl = size
            self.cl = cb
        else:
            self.hl = 0.0
            self.cl = None

    def _pop_p(self):
        q = self.qp
        if q:
            size, cb = q.popleft()
            self.hp = size
            self.cp = cb
        else:
            self.hp = 0.0
            self.cp = None

    def _flush(self, fr: "_Frame", t: float):
        while self.cl is not None and self.hl <= 1e-3:
            cb = self.cl
            self._pop_l()
            fr._run_cb(cb, t)
        while self.cp is not None and self.hp <= 1e-3:
            cb = self.cp
            self._pop_p()
            fr._run_cb(cb, t)

    def send(self, fr: "_Frame", t: float, size, cbdesc, clsidx: int,
             flow: int):
        self._advance(t)
        self._flush(fr, t)
        self.nbytes += size
        if clsidx == CLS_LINE:
            if self.cl is not None:
                self.ql.append((size, cbdesc))
            else:
                self.hl = size
                self.cl = cbdesc
        else:
            if self.cp is not None:
                self.qp.append((size, cbdesc))
            else:
                self.hp = size
                self.cp = cbdesc
        self._schedule(fr, t)

    def backlog(self, t: float) -> float:
        q = sum(sz for d in (self.ql, self.qp) for sz, _ in d)
        return q + sum((r if r > 0.0 else 0.0) for r in (self.hl, self.hp))


class _BShared:
    """SharedLink machinery over list-indexed lanes.  Subclasses fix the
    channel layout and the per-segment rate math (specialized, alloc-free
    `_advance`/`_schedule` instead of the oracle's rate-vector hook)."""

    __slots__ = ("bw", "n", "sched", "heads", "cbs", "qs", "last", "epoch",
                 "nbytes")

    def __init__(self, bw: float, n_chan: int,
                 sched: Optional[LinkSchedule]):
        self.bw = bw
        self.n = n_chan
        self.sched = sched if (sched is not None and sched.bw_active) else None
        self.heads = [0.0] * n_chan
        self.cbs: List[Optional[tuple]] = [None] * n_chan
        self.qs = [deque() for _ in range(n_chan)]
        self.last = 0.0
        self.epoch = 0
        self.nbytes = 0.0

    def _advance(self, t: float):
        raise NotImplementedError

    def _schedule(self, fr: "_Frame", t: float):
        raise NotImplementedError

    def _push_fire(self, fr: "_Frame", t: float, eta: float, best: int):
        sched = self.sched
        if sched is not None:
            nb = sched.next_boundary(t)
            if eta > nb:
                eta = nb  # re-derive rates at the epoch boundary
        s = fr.seq
        heappush(fr.heap, (eta, s, K_FIRE, self, (best, self.epoch)))
        fr.seq = s + 1

    def fire(self, fr: "_Frame", tt: float, c: int, epoch: int):
        if epoch != self.epoch:
            return  # stale
        self._advance(tt)
        if self.heads[c] > 1e-3:
            self._schedule(fr, tt)
            return
        # several lanes can drain at the same instant under fair shares:
        # complete every finished head in channel order, as the oracle does
        heads = self.heads
        cbs = self.cbs
        done = []
        for ch in range(self.n):
            if cbs[ch] is not None and heads[ch] <= 1e-3:
                done.append(cbs[ch])
                self._pop_next(ch)
        self._schedule(fr, tt)
        for cb in done:
            fr._run_cb(cb, tt)

    def _pop_next(self, c: int):
        q = self.qs[c]
        if q:
            size, cb = q.popleft()
            self.heads[c] = size
            self.cbs[c] = cb
        else:
            self.heads[c] = 0.0
            self.cbs[c] = None

    def _flush(self, fr: "_Frame", t: float):
        heads = self.heads
        cbs = self.cbs
        for c in range(self.n):
            while cbs[c] is not None and heads[c] <= 1e-3:
                cb = cbs[c]
                self._pop_next(c)
                fr._run_cb(cb, t)

    def _chan(self, flow: int, clsidx: int) -> int:
        raise NotImplementedError

    def send(self, fr: "_Frame", t: float, size, cbdesc, clsidx: int,
             flow: int):
        self._advance(t)
        self._flush(fr, t)
        self.nbytes += size
        c = self._chan(flow, clsidx)
        if self.cbs[c] is not None:
            self.qs[c].append((size, cbdesc))
        else:
            self.heads[c] = size
            self.cbs[c] = cbdesc
        self._schedule(fr, t)

    def backlog(self, t: float) -> float:
        q = sum(sz for d in self.qs for sz, _ in d)
        return q + sum((r if r > 0.0 else 0.0) for r in self.heads)


class _BSharedFifo(_BShared):
    """SharedFifoLink: one lane per CC flow, fluid fair share.  The rate is
    a single scalar (``fair_split`` is bw / n_active), so advance/schedule
    run without allocating a rate vector."""

    def _chan(self, flow: int, clsidx: int) -> int:
        return flow

    def _advance(self, t: float):
        heads = self.heads
        busy = 0
        for h in heads:
            if h > 0:
                busy += 1
        if not busy:
            if t > self.last:
                self.last = t
            return
        n = self.n
        sched = self.sched
        last = self.last
        if sched is None:
            if last < t:
                # fair_split(busy, bw) * dt, one segment
                r = (self.bw / busy) * (t - last)
                for i in range(n):
                    h = heads[i]
                    if h > 0:
                        v = h - r
                        heads[i] = v if v > 0.0 else 0.0
                self.last = t
            return
        while last < t:
            nb = sched.next_boundary(last)
            seg = t if t < nb else nb
            dt = seg - last
            if dt > 0:
                busy = 0
                for h in heads:
                    if h > 0:
                        busy += 1
                if busy:
                    r = (self.bw * sched.bw_mult(last) / busy) * dt
                    for i in range(n):
                        h = heads[i]
                        if h > 0:
                            v = h - r
                            heads[i] = v if v > 0.0 else 0.0
            last = seg
        self.last = last

    def _schedule(self, fr: "_Frame", t: float):
        self.epoch += 1
        heads = self.heads
        busy = 0
        for h in heads:
            if h > 0:
                busy += 1
        if not busy:
            return
        sched = self.sched
        bw = self.bw * sched.bw_mult(t) if sched is not None else self.bw
        r = bw / busy
        eta = -1.0
        best = 0
        for i in range(self.n):
            h = heads[i]
            if h > 0:
                e2 = t + h / r
                if eta < 0.0 or e2 < eta:
                    eta = e2
                    best = i
        self._push_fire(fr, t, eta, best)


class _BSharedDual(_BShared):
    """SharedDualQueueLink: (flow, class) lanes; channel f*2 is flow f's
    line lane and f*2+1 its page lane — the oracle's channel order.  Rates
    collapse to two scalars (line / page class shares)."""

    __slots__ = ("ls",)

    def __init__(self, bw: float, line_share: float, n_flows: int,
                 sched: Optional[LinkSchedule]):
        super().__init__(bw, 2 * n_flows, sched)
        self.ls = line_share

    def _chan(self, flow: int, clsidx: int) -> int:
        return flow * 2 + clsidx

    def _advance(self, t: float):
        heads = self.heads
        busy = False
        for h in heads:
            if h > 0:
                busy = True
                break
        if not busy:
            if t > self.last:
                self.last = t
            return
        n = self.n
        sched = self.sched
        last = self.last
        ls = self.ls
        while last < t:
            if sched is None:
                seg = t
            else:
                nb = sched.next_boundary(last)
                seg = t if t < nb else nb
            dt = seg - last
            if dt > 0:
                nl = 0
                npg = 0
                for i in range(0, n, 2):
                    if heads[i] > 0:
                        nl += 1
                for i in range(1, n, 2):
                    if heads[i] > 0:
                        npg += 1
                if nl or npg:
                    bw = (self.bw if sched is None
                          else self.bw * sched.bw_mult(last))
                    lr, pr = class_share_split(nl, npg, bw, ls)
                    lrd = lr * dt
                    prd = pr * dt
                    for i in range(0, n, 2):
                        h = heads[i]
                        if h > 0:
                            v = h - lrd
                            heads[i] = v if v > 0.0 else 0.0
                    for i in range(1, n, 2):
                        h = heads[i]
                        if h > 0:
                            v = h - prd
                            heads[i] = v if v > 0.0 else 0.0
            last = seg
        self.last = last

    def _schedule(self, fr: "_Frame", t: float):
        self.epoch += 1
        heads = self.heads
        n = self.n
        nl = 0
        npg = 0
        for i in range(0, n, 2):
            if heads[i] > 0:
                nl += 1
        for i in range(1, n, 2):
            if heads[i] > 0:
                npg += 1
        if not (nl or npg):
            return
        sched = self.sched
        bw = self.bw * sched.bw_mult(t) if sched is not None else self.bw
        lr, pr = class_share_split(nl, npg, bw, self.ls)
        eta = -1.0
        best = 0
        for i in range(n):
            h = heads[i]
            if h > 0:
                r = lr if (i & 1) == 0 else pr
                if r > 0:
                    e2 = t + h / r
                    if eta < 0.0 or e2 < eta:
                        eta = e2
                        best = i
        if eta < 0.0:
            return  # reserved-share starvation: no drainable lane
        self._push_fire(fr, t, eta, best)


# --------------------------------------------------------------------------
# per-cell frame: the transcribed simulator
# --------------------------------------------------------------------------

_GRAN = {"none": 0, "line": 1, "page": 2, "both": 3, "adaptive": 3}


class _Frame:
    """One sweep cell mid-flight: its event heap, cores, caches, links, and
    counters.  ``advance(quantum)`` pops up to ``quantum`` events; the batch
    driver round-robins frames until every heap drains."""

    def __init__(self, cfg: SimConfig, pol: MovementPolicy,
                 preps: List[List[tuple]], workload: str, seed: int,
                 scheds: List[LinkSchedule]):
        self.cfg = cfg
        self.pol = pol
        self.workload = workload
        self.heap: List[tuple] = []
        self.seq = 0
        self.events = 0
        self.cpu_s = 0.0

        # --- localized config scalars (hot-loop reads) ---
        self.mlp = cfg.mlp
        self.llc_lat = cfg.llc_lat
        self.mem_lat = cfg.mem_lat
        self.rml = cfg.remote_mem_lat
        self.net_lat_c = cfg.net_lat
        self.nl0 = cfg.net_lat * 1.0  # == net_lat * lat_mult(t) when inert
        self.lpp = cfg.page_bytes // cfg.line_bytes
        self.pb = cfg.page_bytes
        self.pb_hb = cfg.page_bytes + cfg.header_bytes
        self.lb_hb = cfg.line_bytes + cfg.header_bytes
        self.hb = cfg.header_bytes
        self.il = cfg.inflight_lines
        self.ip = cfg.inflight_pages
        self.pth = cfg.page_throttle_hi
        self.comp4 = cfg.comp_lat / 4
        self.decomp4 = cfg.decomp_lat / 4
        self.nmcs = cfg.n_mcs
        self.ileave = cfg.mc_interleave
        self.lat_active = cfg.lat_jitter > 0.0
        # memory-side resident state (§2.13): the SAME class the oracle
        # instantiates, driven at the same event points — None keeps the
        # legacy mc_place expressions untouched (golden bit-parity)
        self.mem = make_memside(cfg.n_mcs, cfg.mc_interleave,
                                cfg.mc_capacity_pages,
                                cfg.mem_hot_threshold, cfg.switch_lat)

        # --- policy components ---
        self.gran = _GRAN[pol.granularity]
        self.adaptive = pol.granularity == "adaptive"
        self.free = pol.free_transfers
        self.pcr = pol.page_carries_requests
        self.throttle = pol.throttle
        self.compress_on = pol.compression != "off" and cfg.compress
        # movement controller (§2.12): 'fixed' keeps the transcribed inline
        # expressions verbatim (ctrls[i] = None — no dispatch, no perf cost
        # on the legacy grids); any other controller gets one instance per
        # CC and the decision sites route through decide()/observe_*().
        ctrl_name = resolve_controller(pol, cfg)
        self.ctrls: List = []
        self.any_ctrl = ctrl_name != "fixed"

        # --- per-CC / per-core state (transcribing Simulator.__init__) ---
        # Each core is one record list, indexed positionally in the hot loop:
        #   [0] gaps  [1] lines  [2] writes  [3] n  [4] idx  [5] t
        #   [6] tend  [7] out    [8] stalled [9] cc [10] llc [11] llc_cap
        # LLC and local page caches are raw OrderedDicts with the oracle's
        # LRU semantics inlined at each touch point (access = move_to_end +
        # conditional dirty-set; insert = move_to_end + dirty-or when
        # present, else set + popitem(last=False) past capacity).
        parts = tuple(workload.split("+")) if workload else ("",)
        llc_lines = cfg.llc_bytes // cfg.line_bytes
        ncc = len(preps)
        self.ncc = ncc
        self.cc_workload: List[str] = []
        self.loc_d: List[OrderedDict] = []
        self.loc_cap: List[int] = []
        self.rngs: List[np.random.Generator] = []
        self.comp_base: List[float] = []
        self.pending_lines: List[Dict[int, list]] = []
        self.pending_pages: List[Dict[int, list]] = []
        self.retry: List[deque] = []
        self.cc_cores: List[List[int]] = []
        self.cores: List[list] = []

        for i, group in enumerate(preps):
            w = parts[i % len(parts)]
            footprint = int(max(rawmax + 64 for _, _, _, rawmax in group))
            ks = []
            # LRU() clamps capacity to >= 1, as does the oracle
            per_core_llc = max(1, llc_lines // max(1, len(group)))
            for gaps, lines, writes, _rawmax in group:
                k = len(self.cores)
                ks.append(k)
                self.cores.append([gaps, lines, writes, len(lines), 0, 0.0,
                                   -1.0, deque(), False, i, OrderedDict(),
                                   per_core_llc])
            self.cc_cores.append(ks)
            n_pages_total = footprint // cfg.page_bytes + 1
            self.loc_d.append(OrderedDict())
            self.loc_cap.append(
                max(1, int(n_pages_total * cfg.local_mem_frac)))
            self.cc_workload.append(w)
            self.comp_base.append(
                compressibility_of(w if len(parts) > 1 else workload))
            self.rngs.append(np.random.default_rng(seed + 17) if i == 0
                             else np.random.default_rng((seed + 17, i)))
            self.ctrls.append(
                make_controller(ctrl_name, cfg,
                                w if len(parts) > 1 else workload)
                if self.any_ctrl else None)
            self.pending_lines.append({})
            self.pending_pages.append({})
            self.retry.append(deque())

        # --- per-CC counters (accumulated in event order, rolled into
        # Metrics at the end; float accumulators stay float throughout) ---
        self.m_acc = [0] * ncc
        self.m_llc = [0] * ncc
        self.m_local = [0] * ncc
        self.m_rm = [0] * ncc
        self.m_pages = [0] * ncc
        self.m_lines = [0] * ncc
        self.m_wb = [0] * ncc
        self.m_misslat = [0.0] * ncc
        self.m_net = [0.0] * ncc
        self.m_up = [0.0] * ncc
        self.m_saved = [0.0] * ncc
        self.m_stall = [0.0] * ncc

        # --- links (same construction dispatch as Simulator.__init__) ---
        self.scheds = scheds
        bw = cfg.link_bw
        share = cfg.line_share if pol.line_share is None else pol.line_share
        if pol.partitioning == "dual":
            if ncc == 1:
                self.links = [_BDual(bw, share, s) for s in scheds]
            else:
                self.links = [_BSharedDual(bw, share, ncc, s) for s in scheds]
        else:
            if ncc == 1:
                self.links = [_BFifo(bw, s) for s in scheds]
            else:
                self.links = [_BSharedFifo(bw, ncc, s) for s in scheds]
        if cfg.uplink_bw is None:
            self.uplinks = None
        else:
            ubw = cfg.uplink_bw
            req_share = 1.0 - cfg.writeback_share
            if pol.uplink_partitioning == "dual":
                if ncc == 1:
                    self.uplinks = [_BDual(ubw, req_share, s) for s in scheds]
                else:
                    self.uplinks = [_BSharedDual(ubw, req_share, ncc, s)
                                    for s in scheds]
            else:
                if ncc == 1:
                    self.uplinks = [_BFifo(ubw, s) for s in scheds]
                else:
                    self.uplinks = [_BSharedFifo(ubw, ncc, s) for s in scheds]

        # initial events: one core_step per core, global core order (the
        # oracle's Simulator.start), seq numbers 0..n_cores-1
        for k in range(len(self.cores)):
            self._push(0.0, K_CORE, k, 0)

    # ---------------- event plumbing ----------------
    def _push(self, t: float, kind: int, a, b):
        heappush(self.heap, (t, self.seq, kind, a, b))
        self.seq += 1

    def _net_lat(self, mc: int, t: float) -> float:
        if self.lat_active:
            return self.net_lat_c * self.scheds[mc].lat_mult(t)
        return self.nl0

    def _run_cb(self, cb: tuple, tt: float):
        kind = cb[0]
        if kind == "line":
            _, cc, line, mc = cb
            nl = (self.net_lat_c * self.scheds[mc].lat_mult(tt)
                  if self.lat_active else self.nl0)
            s = self.seq
            heappush(self.heap, (tt + nl, s, K_LINE_ARR, cc, line))
            self.seq = s + 1
        elif kind == "page":
            _, cc, page, mc, hx = cb
            nl = (self.net_lat_c * self.scheds[mc].lat_mult(tt)
                  if self.lat_active else self.nl0)
            arrive = tt + nl + (self.decomp4 if hx else 0.0)
            s = self.seq
            heappush(self.heap, (arrive, s, K_PAGE_ARR, cc, page))
            self.seq = s + 1
        elif kind == "up":
            _, mc, extra, link, size, clsidx, cc, inner = cb
            nl = (self.net_lat_c * self.scheds[mc].lat_mult(tt)
                  if self.lat_active else self.nl0)
            s = self.seq
            heappush(self.heap, (tt + nl + self.rml + extra, s,
                                 K_FLIGHT, link, (size, clsidx, cc, inner)))
            self.seq = s + 1
        # "nop": the oracle's `lambda a: None` writeback callback

    def advance(self, limit: int) -> bool:
        """Pop up to ``limit`` events; returns True while events remain.

        This is the batch core's whole hot path: one flat loop with the
        oracle's core_step / complete / arrival handlers (and the LRU
        touch points they make) inlined at each dispatch arm, so an event
        costs a handful of bytecodes instead of a call chain.  Every
        arithmetic expression keeps the oracle's shape and order.
        """
        heap = self.heap
        cores = self.cores
        push = heappush
        pop = heappop
        mlp = self.mlp
        llc_lat = self.llc_lat
        mem_lat = self.mem_lat
        m_acc = self.m_acc
        m_llc = self.m_llc
        m_stall = self.m_stall
        m_misslat = self.m_misslat
        pending_lines = self.pending_lines
        pending_pages = self.pending_pages
        retry = self.retry
        loc_d = self.loc_d
        loc_cap = self.loc_cap
        miss = self._miss
        ctrls = self.ctrls if self.any_ctrl else None
        n_ev = 0
        while heap and n_ev < limit:
            t, _, kind, a, b = pop(heap)
            n_ev += 1
            if kind == K_CORE:
                # oracle: Simulator.core_step.  Request `done` flags are
                # only flipped by events, so they are fixed for the whole
                # call; `out` mutates only on the misses issued here.
                C = cores[a]
                C[8] = False
                ct = C[5]
                if ct > t:
                    t = ct
                gaps = C[0]
                lines = C[1]
                writes = C[2]
                n = C[3]
                idx = C[4]
                out = C[7]
                d = C[10]
                cc = C[9]
                acc = 0
                hits = 0
                while idx < n:
                    while out and out[0][4]:
                        out.popleft()
                    if len(out) >= mlp:
                        C[8] = True
                        C[4] = idx
                        C[5] = t
                        m_acc[cc] += acc
                        m_llc[cc] += hits
                        m_stall[cc] += 1  # one per mlp-window fill
                        break  # resumed by completion of the oldest request
                    line = lines[idx]
                    wr = writes[idx]
                    t += gaps[idx]
                    idx += 1
                    acc += 1
                    if line in d:  # LLC access(line, wr)
                        d.move_to_end(line)
                        if wr:
                            d[line] = True
                        hits += 1
                        t += llc_lat
                        continue
                    t += llc_lat  # miss detection
                    C[4] = idx
                    miss(cc, C, a, line, wr, t)
                    idx = C[4]
                else:
                    C[4] = idx
                    C[5] = t
                    if t > C[6]:
                        C[6] = t
                    m_acc[cc] += acc
                    m_llc[cc] += hits
            elif kind == K_COMPLETE:
                # oracle: Simulator.complete (a is the request record)
                a[4] = True
                k = a[3]
                C = cores[k]
                m_misslat[C[9]] += t - a[1]
                if C[8]:
                    out = C[7]
                    if out and out[0][4]:
                        s = self.seq
                        push(heap, (t, s, K_CORE, k, 0))
                        self.seq = s + 1
            elif kind == K_FLIGHT:
                size, clsidx, flow, cbdesc = b
                a.send(self, t, size, cbdesc, clsidx, flow)
            elif kind == K_LINE_ARR:
                # oracle: on_line_arrival (a = cc, b = line): LLC-insert +
                # complete every waiter, then drain the retry queue
                if ctrls is not None:
                    ctrls[a].observe_line(t)
                reqs = pending_lines[a].pop(b, ())
                for r in reqs:
                    if not r[4]:
                        k = r[3]
                        C = cores[k]
                        d = C[10]
                        wr = r[2]
                        if b in d:
                            d.move_to_end(b)
                            if wr:
                                d[b] = True
                        else:
                            d[b] = wr
                            if len(d) > C[11]:
                                d.popitem(last=False)
                        r[4] = True
                        m_misslat[C[9]] += t - r[1]
                        if C[8]:
                            out = C[7]
                            if out and out[0][4]:
                                s = self.seq
                                push(heap, (t, s, K_CORE, k, 0))
                                self.seq = s + 1
                if retry[a]:
                    self._drain_retry(a, t)
            elif kind == K_FIRE:
                c, epoch = b
                a.fire(self, t, c, epoch)
            elif kind == K_PAGE_ARR:
                # oracle: on_page_arrival (a = cc, b = page): install the
                # page (dirty eviction -> writeback), complete waiters at
                # t + mem_lat (read from local memory), drain retries
                if ctrls is not None:
                    ctrls[a].observe_page(t)
                loc = loc_d[a]
                if b in loc:
                    loc.move_to_end(b)
                    # insert(page): present-entry dirty bit is unchanged
                else:
                    loc[b] = False
                    if len(loc) > loc_cap[a]:
                        tag, dirty = loc.popitem(last=False)
                        if dirty:
                            self._send_writeback(a, tag, t)
                reqs = pending_pages[a].pop(b, ())
                tm = t + mem_lat
                for r in reqs:
                    if not r[4]:
                        k = r[3]
                        C = cores[k]
                        d = C[10]
                        line = r[0]
                        wr = r[2]
                        if line in d:
                            d.move_to_end(line)
                            if wr:
                                d[line] = True
                        else:
                            d[line] = wr
                            if len(d) > C[11]:
                                d.popitem(last=False)
                        r[4] = True
                        m_misslat[C[9]] += tm - r[1]
                        if C[8]:
                            out = C[7]
                            if out and out[0][4]:
                                s = self.seq
                                push(heap, (tm, s, K_CORE, k, 0))
                                self.seq = s + 1
                if retry[a]:
                    self._drain_retry(a, t)
            elif kind == K_TXDONE:
                self._run_cb(a, t)
            else:  # K_WBSEND
                size, flow = b
                a.send(self, t, size, NOP, CLS_PAGE, flow)
        self.events += n_ev
        return bool(heap)

    # ---------------- miss handling (oracle: Simulator.miss) -------------
    def _local_hit(self, cc: int, C: list, k: int, line: int, wr: bool,
                   t: float):
        self.m_local[cc] += 1
        d = C[10]
        if line in d:  # LLC insert(line, wr)
            d.move_to_end(line)
            if wr:
                d[line] = True
        else:
            d[line] = wr
            if len(d) > C[11]:
                d.popitem(last=False)
        req = [line, t, wr, k, False]
        if not wr:
            C[7].append(req)
        self._push(t + self.mem_lat, K_COMPLETE, req, 0)

    def _miss(self, cc: int, C: list, k: int, line: int, wr: bool, t: float):
        gran = self.gran
        if gran == 0:  # 'none': every miss is local DRAM
            self._local_hit(cc, C, k, line, wr, t)
            return
        if gran == 1:  # 'line': line movement only
            self.m_rm[cc] += 1
            req = [line, t, wr, k, False]
            if not wr:
                C[7].append(req)
            self._fetch_line(cc, line, t, req)
            return
        page = line // self.lpp
        loc = self.loc_d[cc]
        if page in loc:  # page-cache access(page, wr)
            loc.move_to_end(page)
            if wr:
                loc[page] = True
            self._local_hit(cc, C, k, line, wr, t)
            return
        self.m_rm[cc] += 1
        if self.free:  # idealized locality bound
            self._insert_page(cc, page, t)
            self.m_pages[cc] += 1
            self.m_local[cc] -= 1  # counted as remote, not a local hit
            self._local_hit(cc, C, k, line, wr, t)
            return
        if gran == 2:  # 'page': requests ride the page migration
            req = [line, t, wr, k, False]
            if not wr:
                C[7].append(req)
            pp = self.pending_pages[cc]
            lst = pp.get(page)
            if lst is not None:
                lst.append(req)
            else:
                pp[page] = [req]
                self._send_page(cc, page, t)
            return
        self._composed_miss(cc, C, k, line, wr, t)

    def _composed_miss(self, cc: int, C: list, k: int, line: int, wr: bool,
                       t: float):
        pl = self.pending_lines[cc]
        pp = self.pending_pages[cc]
        page = line // self.lpp
        req = [line, t, wr, k, False]
        if not wr:
            C[7].append(req)
        lu = len(pl) / self.il
        pu = len(pp) / self.ip

        # movement controller (§2.12): observe-then-decide, in the
        # oracle's order; None is the transcribed 'fixed' fast path
        plist = pp.get(page)
        ctrl = self.ctrls[cc]
        if ctrl is not None:
            ctrl.observe_miss(plist is not None)
            d = ctrl.decide(self._ctrl_obs(ctrl, cc, page, t, lu, pu))

        # coalesce with an inflight page migration
        if plist is not None:
            if self.pcr:
                plist.append(req)
            llist = pl.get(line)
            if llist is not None:
                llist.append(req)
            elif self.adaptive:
                if (selection_races_line(lu, pu) if ctrl is None
                        else d.race_line):
                    pl[line] = [req]
                    self._fetch_line_daemon(cc, line, t)
            elif not self.pcr:
                pl[line] = [req]
                self._fetch_line_daemon(cc, line, t)
            return

        # triggering miss: BOTH by default
        if self.throttle:
            if ctrl is None:
                issue_page = pu < self.pth
                issue_line = lu < 1.0 or line in pl
            else:
                issue_page = d.issue_page
                issue_line = d.issue_line or line in pl
            if not issue_line and not issue_page:
                self.retry[cc].append(req)  # buffers full: park for re-issue
                return
        else:
            issue_page = issue_line = True

        promote = False
        if issue_line:
            llist = pl.get(line)
            if llist is not None:
                llist.append(req)
            else:
                pl[line] = [req]
                promote = self._fetch_line_daemon(cc, line, t)
        if issue_page:
            waiting = pp.setdefault(page, [])
            if self.pcr:
                waiting.append(req)
            self._send_page(cc, page, t)
        if promote:
            # oracle ordering: promotion runs after the demand page-issue
            # bookkeeping so a triggering miss never double-sends the page
            self._maybe_promote(cc, page, t)

    def _drain_retry(self, cc: int, t: float):
        rq = self.retry[cc]
        n = len(rq)
        pl = self.pending_lines[cc]
        pp = self.pending_pages[cc]
        ctrl = self.ctrls[cc]
        for _ in range(n):
            req = rq.popleft()
            if req[R_DONE]:
                continue
            line = req[R_ADDR]
            lu = len(pl) / self.il
            pu = len(pp) / self.ip
            page = line // self.lpp
            if ctrl is not None:
                d = ctrl.decide(self._ctrl_obs(ctrl, cc, page, t, lu, pu))
            llist = pl.get(line)
            if llist is not None:
                llist.append(req)
            elif page in pp:
                pp[page].append(req)
            elif (lu < 1.0 if ctrl is None else d.issue_line):
                pl[line] = [req]
                if self._fetch_line_daemon(cc, line, t):
                    self._maybe_promote(cc, page, t)
            elif (pu < self.pth if ctrl is None else d.issue_page):
                pp[page] = [req]
                self._send_page(cc, page, t)
            else:
                rq.append(req)

    # ---------------- transfers ----------------
    def _request_flight(self, cc: int, mc: int, t: float, extra: float,
                        link, size, clsidx: int, cbdesc: tuple):
        if self.uplinks is None:
            self._push(t + self._net_lat(mc, t) + self.rml + extra,
                       K_FLIGHT, link, (size, clsidx, cc, cbdesc))
            return
        self.m_up[cc] += self.hb
        self.uplinks[mc].send(
            self, t, self.hb,
            ("up", mc, extra, link, size, clsidx, cc, cbdesc), CLS_LINE, cc)

    def _fetch_line(self, cc: int, line: int, t: float, req: list):
        pl = self.pending_lines[cc]
        lst = pl.get(line)
        if lst is not None:  # coalesce with the inflight fetch
            lst.append(req)
            return
        pl[line] = [req]
        self.m_lines[cc] += 1
        page = line // self.lpp
        if self.mem is None:
            mc, xl = mc_place(page, self.nmcs, self.ileave), 0.0
        else:  # oracle: _fetch_line — the promotion signal is moot for
            # line-granularity policies (no local page cache)
            mc, xl, _ = self.mem.touch(cc, page, "line")
        size = self.lb_hb
        self._request_flight(cc, mc, t, xl, self.links[mc], size, CLS_LINE,
                             ("line", cc, line, mc))
        self.m_net[cc] += size

    def _fetch_line_daemon(self, cc: int, line: int, t: float) -> bool:
        # oracle: _fetch_line_daemon — returns the §2.13 hot-page
        # promotion signal for the caller to act on after page-issue
        # bookkeeping settles
        self.m_lines[cc] += 1
        page = line // self.lpp
        if self.mem is None:
            mc, xl, promote = (mc_place(page, self.nmcs, self.ileave),
                               0.0, False)
        else:
            mc, xl, promote = self.mem.touch(cc, page, "line")
        size = self.lb_hb
        self.m_net[cc] += size
        self._request_flight(cc, mc, t, xl, self.links[mc], size, CLS_LINE,
                             ("line", cc, line, mc))
        return promote

    def _maybe_promote(self, cc: int, page: int, t: float):
        # oracle: _maybe_promote — hot-page promotion toward the owning
        # CC, throttled by the backlog signal (inflight page buffer has
        # room), waiterless like the oracle's pending_pages[page] = []
        pp = self.pending_pages[cc]
        if page in pp or page in self.loc_d[cc]:
            return
        if len(pp) >= self.ip:
            return
        self.mem.promotions += 1
        pp[page] = []
        self._send_page(cc, page, t)

    def _ctrl_obs(self, ctrl, cc: int, page: int, t: float,
                  lu: float, pu: float) -> Observation:
        # oracle: Simulator._obs — the uplink backlog (toward the page's
        # MC) only for controllers that declare needs_uplink; the
        # resident-MC read is the pure peek (§2.13), never a touch
        ub = 0.0
        if ctrl.needs_uplink and self.uplinks is not None:
            mc = (mc_place(page, self.nmcs, self.ileave)
                  if self.mem is None else self.mem.peek(cc, page))
            ub = self.uplinks[mc].backlog(t)
        return Observation(t, lu, pu, ub)

    def _send_page(self, cc: int, page: int, t: float):
        if self.mem is None:
            mc, xl = mc_place(page, self.nmcs, self.ileave), 0.0
        else:  # oracle: _send_page — 'page' touch resets the hotness count
            mc, xl, _ = self.mem.touch(cc, page, "page")
        raw = self.pb_hb
        size = raw
        extra = 0.0
        if self.compress_on:
            ctrl = self.ctrls[cc]
            pu = len(self.pending_pages[cc]) / self.ip
            if ctrl is None:
                comp = pu > PAGE_FAST
            else:
                lu = len(self.pending_lines[cc]) / self.il
                comp = ctrl.decide(
                    self._ctrl_obs(ctrl, cc, page, t, lu, pu)).compress
            if comp:
                base = self.comp_base[cc]
                r = self.rngs[cc].normal(base, 0.15 * base)
                ratio = r if r > 1.0 else 1.0  # max(1.0, r)
                size = self.pb / ratio + self.hb
                extra = self.comp4
                self.m_saved[cc] += raw - size
        self.m_net[cc] += size
        self.m_pages[cc] += 1
        # xl charges the spilled-resident detour (§2.13) on the request
        # path; decompression stays keyed on `extra` alone (bool below)
        self._request_flight(cc, mc, t, extra + xl, self.links[mc], size,
                             CLS_PAGE, ("page", cc, page, mc, bool(extra)))

    def _send_writeback(self, cc: int, page: int, t: float):
        if self.mem is None:
            mc, xl = mc_place(page, self.nmcs, self.ileave), 0.0
        else:  # oracle: _send_writeback — 'wb' touch re-allocates a
            # backing page the pool evicted
            mc, xl, _ = self.mem.touch(cc, page, "wb")
        raw = self.pb_hb
        size = raw
        extra = 0.0
        self.m_wb[cc] += 1
        ctrl = self.ctrls[cc]
        if self.uplinks is None:
            # legacy: writeback injected into the *downlink* queue
            link = self.links[mc]
            if self.compress_on:
                pu = len(self.pending_pages[cc]) / self.ip
                if ctrl is None:
                    comp = pu > PAGE_FAST
                else:
                    lu = len(self.pending_lines[cc]) / self.il
                    comp = ctrl.decide(
                        self._ctrl_obs(ctrl, cc, page, t, lu, pu)).compress
                if comp:
                    base = self.comp_base[cc]
                    r = self.rngs[cc].normal(base, 0.15 * base)
                    ratio = r if r > 1.0 else 1.0
                    size = self.pb / ratio + self.hb
                    extra = self.comp4
                    self.m_saved[cc] += raw - size
            self.m_net[cc] += size
            self._push(t + extra + xl, K_WBSEND, link, (size, cc))
            return
        up = self.uplinks[mc]
        if ctrl is None:
            comp = self.compress_on and up.backlog(t) > self.pb
        else:
            lu = len(self.pending_lines[cc]) / self.il
            pu = len(self.pending_pages[cc]) / self.ip
            comp = self.compress_on and ctrl.decide(
                Observation(t, lu, pu, up.backlog(t))).compress_writeback
        if comp:
            base = self.comp_base[cc]
            r = self.rngs[cc].normal(base, 0.15 * base)
            ratio = r if r > 1.0 else 1.0
            size = self.pb / ratio + self.hb
            extra = self.comp4
            self.m_saved[cc] += raw - size
        self.m_up[cc] += size
        self._push(t + extra + xl, K_WBSEND, up, (size, cc))

    def _insert_page(self, cc: int, page: int, t: float):
        # page-cache insert(page); dirty eviction past capacity -> writeback
        loc = self.loc_d[cc]
        if page in loc:
            loc.move_to_end(page)
            # present-entry dirty bit is unchanged (dirty-or with False)
        else:
            loc[page] = False
            if len(loc) > self.loc_cap[cc]:
                tag, dirty = loc.popitem(last=False)
                if dirty:
                    self._send_writeback(cc, tag, t)

    # arrivals (oracle: on_line_arrival / on_page_arrival) are inlined in
    # advance() at the K_LINE_ARR / K_PAGE_ARR dispatch arms.

    # ---------------- results ----------------
    def result(self) -> Metrics:
        """Assemble Metrics exactly as Simulator.run() does: per-CC rollup
        in CC order, cycles as the makespan, per_cc entries for n_ccs>1."""
        scheme = self.pol.name
        ms = []
        for i in range(self.ncc):
            wl = self.workload if self.ncc == 1 else self.cc_workload[i]
            mm = Metrics(scheme=scheme, workload=wl)
            mm.accesses = self.m_acc[i]
            mm.llc_hits = self.m_llc[i]
            mm.local_hits = self.m_local[i]
            mm.remote_misses = self.m_rm[i]
            mm.miss_latency_sum = self.m_misslat[i]
            mm.net_bytes = self.m_net[i]
            mm.uplink_bytes = self.m_up[i]
            mm.pages_moved = self.m_pages[i]
            mm.lines_moved = self.m_lines[i]
            mm.writebacks = self.m_wb[i]
            mm.bytes_saved_compression = self.m_saved[i]
            mm.stall_episodes = self.m_stall[i]
            mm.cycles = max(self.cores[k][6] for k in self.cc_cores[i])
            ms.append(mm)
        if self.ncc == 1:
            self._memside_rollup(ms[0])
            return ms[0]
        m = Metrics(scheme=scheme, workload=self.workload)
        for i, cc in enumerate(ms):
            m.accesses += cc.accesses
            m.llc_hits += cc.llc_hits
            m.local_hits += cc.local_hits
            m.remote_misses += cc.remote_misses
            m.miss_latency_sum += cc.miss_latency_sum
            m.net_bytes += cc.net_bytes
            m.uplink_bytes += cc.uplink_bytes
            m.pages_moved += cc.pages_moved
            m.lines_moved += cc.lines_moved
            m.writebacks += cc.writebacks
            m.bytes_saved_compression += cc.bytes_saved_compression
            m.stall_episodes += cc.stall_episodes
            d = cc.as_dict()
            d.pop("per_cc")
            d["cc"] = i
            m.per_cc.append(d)
        m.cycles = max(cc.cycles for cc in ms)
        self._memside_rollup(m)
        return m

    def _memside_rollup(self, m: Metrics):
        # oracle: Simulator._memside_rollup — §2.13 pool counters are
        # cell-global (the pool is shared), so per_cc entries stay zero
        if self.mem is not None:
            m.mc_spills = self.mem.spills
            m.mc_evictions = self.mem.evictions
            m.mc_promotions = self.mem.promotions


# --------------------------------------------------------------------------
# batch driver
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class BatchCell:
    """One sweep cell, fully resolved (run_one's signature as data)."""

    workload: str
    scheme: Any
    cfg: SimConfig
    seed: int = 0
    n_accesses: int = 60_000
    footprint: int = 16 << 20
    n_jobs: int = 1


@dataclass
class BatchState:
    """Struct-of-arrays view over the batch, synced at every lockstep
    quantum boundary: per-cell core cursors, fluid-link backlogs, and
    selection-unit counters.  This is the driver's observation surface —
    progress/throughput reporting reads these arrays, never the frames."""

    n_cells: int
    # core cursors: furthest core time and total issued accesses per cell
    t_now: np.ndarray = field(default=None)          # (n_cells,) float64
    accesses: np.ndarray = field(default=None)       # (n_cells,) int64
    events: np.ndarray = field(default=None)         # (n_cells,) int64
    # fluid-link backlogs and selection-unit occupancy per cell
    link_backlog: np.ndarray = field(default=None)   # (n_cells,) float64
    inflight_lines: np.ndarray = field(default=None)  # (n_cells,) int64
    inflight_pages: np.ndarray = field(default=None)  # (n_cells,) int64
    retry_depth: np.ndarray = field(default=None)    # (n_cells,) int64
    done: np.ndarray = field(default=None)           # (n_cells,) bool

    def __post_init__(self):
        n = self.n_cells
        self.t_now = np.zeros(n)
        self.accesses = np.zeros(n, dtype=np.int64)
        self.events = np.zeros(n, dtype=np.int64)
        self.link_backlog = np.zeros(n)
        self.inflight_lines = np.zeros(n, dtype=np.int64)
        self.inflight_pages = np.zeros(n, dtype=np.int64)
        self.retry_depth = np.zeros(n, dtype=np.int64)
        self.done = np.zeros(n, dtype=bool)

    def sync(self, i: int, fr: _Frame, done: bool):
        t = max((C[5] for C in fr.cores), default=0.0)
        self.t_now[i] = t
        self.accesses[i] = sum(fr.m_acc)
        self.events[i] = fr.events
        self.link_backlog[i] = sum(ln.backlog(t) for ln in fr.links)
        self.inflight_lines[i] = sum(len(d) for d in fr.pending_lines)
        self.inflight_pages[i] = sum(len(d) for d in fr.pending_pages)
        self.retry_depth[i] = sum(len(q) for q in fr.retry)
        self.done[i] = done


@dataclass
class BatchResult:
    metrics: List[Metrics]
    cpu_s: List[float]
    state: BatchState
    events: int = 0


def _build_frame(cell: BatchCell, tp: TracePool, sp: SchedPool) -> _Frame:
    """Resolve one cell into a frame, replicating run_one's trace-group
    derivation (seeding, '+'-mix round-robin, per-thread splits)."""
    cfg = cell.cfg
    pol = get_policy(cell.scheme)
    n_ccs = max(1, cfg.n_ccs)
    wl = cell.workload
    parts = tuple(wl.split("+")) if wl else (wl,)
    n_threads = max(1, cfg.n_cores) * max(1, cell.n_jobs)
    per = max(1, cell.n_accesses // n_threads)
    gs = cfg.gap_scale
    if n_ccs == 1 and len(parts) == 1:
        preps = [[tp.get(wl, cell.seed + j, cell.footprint, per, gs)
                  for j in range(n_threads)]]
    else:
        preps = [
            [tp.get(parts[c % len(parts)], cell.seed + c * n_threads + j,
                    cell.footprint, per, gs)
             for j in range(n_threads)]
            for c in range(n_ccs)
        ]
    scheds = [sp.get(cfg.jitter_period, cfg.bw_jitter, cfg.lat_jitter,
                     cfg.jitter_seed * 1000 + mc)
              for mc in range(cfg.n_mcs)]
    return _Frame(cfg, pol, preps, wl, cell.seed, scheds)


def run_batch(cells: Sequence[BatchCell], quantum: int = 8192,
              trace_pool: Optional[TracePool] = None,
              sched_pool: Optional[SchedPool] = None) -> BatchResult:
    """Advance every cell to completion in lockstep rounds of ``quantum``
    events, sharing trace/schedule pools across the batch.  Results are
    positionally aligned with ``cells`` and bit-identical to running each
    cell through the oracle (``run_one``)."""
    tp = trace_pool if trace_pool is not None else TracePool()
    sp = sched_pool if sched_pool is not None else SchedPool()
    frames: List[_Frame] = []
    for cell in cells:
        reason = uncovered_reason(cell.cfg, cell.scheme)
        if reason is not None:
            raise ValueError(
                f"batch engine does not cover cell {cell!r}: {reason}; "
                f"route it to the oracle (see covers())")
        t0 = time.process_time()
        fr = _build_frame(cell, tp, sp)
        fr.cpu_s += time.process_time() - t0
        frames.append(fr)
    state = BatchState(len(frames))
    active = list(range(len(frames)))
    # the hot loop allocates only short-lived tuples/lists that refcounting
    # alone reclaims; generational GC passes just scan the (large) live heap
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        while active:
            nxt = []
            for i in active:
                fr = frames[i]
                t0 = time.process_time()
                more = fr.advance(quantum)
                fr.cpu_s += time.process_time() - t0
                state.sync(i, fr, not more)
                if more:
                    nxt.append(i)
            active = nxt
    finally:
        if gc_was_enabled:
            gc.enable()
    return BatchResult(
        metrics=[fr.result() for fr in frames],
        cpu_s=[fr.cpu_s for fr in frames],
        state=state,
        events=int(state.events.sum()),
    )
