"""Public simulation API + the paper's experiment sweeps.

  run_one(workload, scheme, ...)          -> Metrics
  fig2(...)   scheme x workload grid      (paper Fig. 2)
  fig4_top(...) bw x n_mcs x workload     (paper Fig. 4 top)
  fig4_bottom(...) multi-job interference (paper Fig. 4 bottom)
  paper_claims(...) geomean speedups of daemon over page
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional

from repro.core.sim.config import SCHEMES, Metrics, SimConfig
from repro.core.sim.engine import simulate
from repro.core.sim.trace import WORKLOADS, generate

DEFAULT_WORKLOADS = tuple(WORKLOADS)


def run_one(
    workload: str,
    scheme: str,
    cfg: Optional[SimConfig] = None,
    *,
    seed: int = 0,
    n_accesses: int = 60_000,
    footprint: int = 16 << 20,
    n_jobs: int = 1,
) -> Metrics:
    """One application = cfg.n_cores threads of the workload (multicore CC);
    n_jobs > 1 stacks additional independent applications on the same CC."""
    cfg = cfg or SimConfig()
    n_threads = max(1, cfg.n_cores) * max(1, n_jobs)
    per = max(1, n_accesses // n_threads)
    traces = [generate(workload, seed=seed + j, footprint=footprint, n=per)
              for j in range(n_threads)]
    return simulate(cfg, scheme, traces, workload=workload, seed=seed)


def geomean(xs: Iterable[float]) -> float:
    xs = [max(x, 1e-12) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def fig2(
    cfg: Optional[SimConfig] = None,
    workloads: Iterable[str] = DEFAULT_WORKLOADS,
    schemes: Iterable[str] = SCHEMES,
    **kw,
) -> Dict[str, Dict[str, Metrics]]:
    """Slowdown grid: scheme x workload (normalize to 'local' outside)."""
    out: Dict[str, Dict[str, Metrics]] = {}
    for w in workloads:
        out[w] = {s: run_one(w, s, cfg, **kw) for s in schemes}
    return out


def slowdowns(grid: Dict[str, Dict[str, Metrics]]) -> Dict[str, Dict[str, float]]:
    """cycles(scheme)/cycles(local) per workload."""
    out = {}
    for w, row in grid.items():
        base = row["local"].cycles
        out[w] = {s: m.cycles / base for s, m in row.items()}
    return out


def fig4_top(
    workloads: Iterable[str] = ("pr", "dr", "st", "nw"),
    bw_fracs: Iterable[float] = (0.5, 0.25, 0.125),
    n_mcs_list: Iterable[int] = (1, 2, 4),
    **kw,
) -> List[dict]:
    """Speedup of daemon over page across network/MC configurations."""
    rows = []
    for w in workloads:
        for bw in bw_fracs:
            for n_mcs in n_mcs_list:
                cfg = SimConfig(link_bw_frac=bw, n_mcs=n_mcs)
                mp = run_one(w, "page", cfg, **kw)
                md = run_one(w, "daemon", cfg, **kw)
                rows.append(
                    {
                        "workload": w,
                        "bw_frac": bw,
                        "n_mcs": n_mcs,
                        "speedup": mp.cycles / md.cycles,
                        "access_cost_ratio": mp.avg_access_cost / max(md.avg_access_cost, 1e-9),
                        "net_bytes_ratio": mp.net_bytes / max(md.net_bytes, 1e-9),
                    }
                )
    return rows


def fig4_bottom(
    workloads: Iterable[str] = ("pr", "dr", "st", "nw"),
    n_jobs: int = 4,
    **kw,
) -> List[dict]:
    """Multiple concurrent jobs on one CC sharing the network and one MC."""
    rows = []
    for w in workloads:
        mp = run_one(w, "page", n_jobs=n_jobs, **kw)
        md = run_one(w, "daemon", n_jobs=n_jobs, **kw)
        rows.append(
            {
                "workload": w,
                "n_jobs": n_jobs,
                "speedup": mp.cycles / md.cycles,
                "access_cost_ratio": mp.avg_access_cost / max(md.avg_access_cost, 1e-9),
            }
        )
    return rows


def paper_claims(
    bw_fracs: Iterable[float] = (0.25, 0.125), **kw
) -> dict:
    """Geomean daemon-vs-page improvements over the workload suite across the
    paper's network operating range — the quantities the paper reports as
    3.06x (access-cost reduction) and 2.39x (performance)."""
    perf, cost, per_bw = [], [], {}
    for bw in bw_fracs:
        cfg = SimConfig(link_bw_frac=bw)
        grid = fig2(cfg, schemes=("page", "daemon"), **kw)
        p = [row["page"].cycles / row["daemon"].cycles for row in grid.values()]
        c = [
            row["page"].avg_access_cost / max(row["daemon"].avg_access_cost, 1e-9)
            for row in grid.values()
        ]
        per_bw[bw] = {
            "perf": geomean(p),
            "cost": geomean(c),
            "per_workload": {w: grid[w]["page"].cycles / grid[w]["daemon"].cycles for w in grid},
        }
        perf += p
        cost += c
    return {
        "perf_speedup_geomean": geomean(perf),
        "access_cost_reduction_geomean": geomean(cost),
        "per_bw": per_bw,
    }
