"""Public simulation API + the paper's experiment sweeps.

Every figure is one declarative :class:`~repro.core.sim.sweep.Sweep` executed
by the parallel sweep engine (DESIGN.md §6); pass ``workers=N`` to fan cells
out over a process pool (results are identical to the serial run).

  run_one(workload, scheme, ...)          -> Metrics
  fig2(...)   scheme x workload grid      (paper Fig. 2)
  fig4_top(...) bw x n_mcs x workload     (paper Fig. 4 top)
  fig4_bottom(...) multi-job interference (paper Fig. 4 bottom)
  fig5_scalability(...) n_ccs x scheme x workload-mix (multi-CC contention)
  fig6_ablation(...) ablation policies x workloads (synergy decomposition)
  fig7_uplink(...) uplink_bw x write-heavy workload x n_ccs (uplink contention)
  fig8_kernels(...) captured Pallas-kernel streams x policy x bandwidth
  fig11_controllers(...) movement controller x scheme on the fig6/7/8 grids
  fig12_memside(...) placement x capacity-pressure x tenant-mix memory pool
  paper_claims(...) geomean speedups of daemon over page

Schemes and workloads are registry names (policy.py / trace.py); every
registered composition is a valid axis value.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from repro.core.sim.config import SCHEMES, Metrics, SimConfig
from repro.core.sim.sweep import (
    Sweep,
    SweepResult,
    geomean,
    run_one,
    run_sweep,
    scheme_ratio,
)
from repro.core.sim.trace import DEFAULT_SUITE

# the paper's eight-workload suite, pinned explicitly (NOT "every registered
# workload") so registering a new source never changes the committed grids
DEFAULT_WORKLOADS = DEFAULT_SUITE


def _sweep_kw(kw: dict) -> dict:
    """Map run_one-style kwargs (seed/n_accesses/footprint) onto the
    corresponding Sweep fields; n_jobs is a per-figure axis, not mapped here."""
    out = {}
    if "n_accesses" in kw:
        out["n_accesses"] = kw.pop("n_accesses")
    if "footprint" in kw:
        out["footprint"] = kw.pop("footprint")
    if "seed" in kw:
        out["base_seed"] = kw.pop("seed")
    if kw:
        raise TypeError(f"unexpected kwargs: {sorted(kw)}")
    return out


def fig2(
    cfg: Optional[SimConfig] = None,
    workloads: Iterable[str] = DEFAULT_WORKLOADS,
    schemes: Iterable[str] = SCHEMES,
    *,
    workers: Optional[int] = None,
    n_jobs: int = 1,
    **kw,
) -> Dict[str, Dict[str, Metrics]]:
    """Slowdown grid: scheme x workload (normalize to 'local' outside)."""
    res = fig2_sweep(cfg, workloads, schemes, workers=workers, n_jobs=n_jobs, **kw)
    out: Dict[str, Dict[str, Metrics]] = {w: {} for w in res.axes["workload"]}
    for r in res.rows:
        out[r.axes["workload"]][r.axes["scheme"]] = r.metrics
    return out


def fig2_spec(
    cfg: Optional[SimConfig] = None,
    workloads: Iterable[str] = DEFAULT_WORKLOADS,
    schemes: Iterable[str] = SCHEMES,
    *,
    n_jobs: int = 1,
    **kw,
) -> Sweep:
    """The canonical Fig. 2 grid declaration (shared by the API and the
    benchmark script, so the 'fig2' BENCH_sim.json entry has one meaning)."""
    axes = {"workload": tuple(workloads), "scheme": tuple(schemes)}
    if n_jobs != 1:
        axes["n_jobs"] = (n_jobs,)
    return Sweep(name="fig2", axes=axes, base=cfg or SimConfig(), **_sweep_kw(kw))


def fig2_sweep(
    cfg: Optional[SimConfig] = None,
    workloads: Iterable[str] = DEFAULT_WORKLOADS,
    schemes: Iterable[str] = SCHEMES,
    *,
    workers: Optional[int] = None,
    n_jobs: int = 1,
    **kw,
) -> SweepResult:
    """The Fig. 2 grid as an executed SweepResult (rows carry full Metrics)."""
    return run_sweep(fig2_spec(cfg, workloads, schemes, n_jobs=n_jobs, **kw),
                     workers=workers)


def slowdowns(grid: Dict[str, Dict[str, Metrics]]) -> Dict[str, Dict[str, float]]:
    """cycles(scheme)/cycles(local) per workload."""
    out = {}
    for w, row in grid.items():
        base = row["local"].cycles
        out[w] = {s: m.cycles / base for s, m in row.items()}
    return out


def fig4_top_spec(
    workloads: Iterable[str] = ("pr", "dr", "st", "nw"),
    bw_fracs: Iterable[float] = (0.5, 0.25, 0.125),
    n_mcs_list: Iterable[int] = (1, 2, 4),
    *,
    cfg: Optional[SimConfig] = None,
    n_jobs: int = 1,
    **kw,
) -> Sweep:
    """The canonical Fig. 4 (top) grid declaration (shared by the API and
    the benchmark script, so the 'fig4_top' BENCH_sim.json entry has one
    meaning)."""
    axes = {
        "workload": tuple(workloads),
        "link_bw_frac": tuple(bw_fracs),
        "n_mcs": tuple(n_mcs_list),
        "scheme": ("page", "daemon"),
    }
    if n_jobs != 1:
        axes["n_jobs"] = (n_jobs,)
    return Sweep(name="fig4_top", axes=axes, base=cfg or SimConfig(),
                 **_sweep_kw(kw))


def fig4_top(
    workloads: Iterable[str] = ("pr", "dr", "st", "nw"),
    bw_fracs: Iterable[float] = (0.5, 0.25, 0.125),
    n_mcs_list: Iterable[int] = (1, 2, 4),
    *,
    cfg: Optional[SimConfig] = None,
    workers: Optional[int] = None,
    n_jobs: int = 1,
    **kw,
) -> List[dict]:
    """Speedup of daemon over page across network/MC configurations."""
    sw = fig4_top_spec(workloads, bw_fracs, n_mcs_list, cfg=cfg,
                       n_jobs=n_jobs, **kw)
    res = run_sweep(sw, workers=workers)
    g = res.grid("workload", "link_bw_frac", "n_mcs", "scheme")
    rows = []
    for w in sw.axes["workload"]:
        for bw in sw.axes["link_bw_frac"]:
            for n_mcs in sw.axes["n_mcs"]:
                mp = g[(w, bw, n_mcs, "page")].metrics
                md = g[(w, bw, n_mcs, "daemon")].metrics
                rows.append(
                    {
                        "workload": w,
                        "bw_frac": bw,
                        "n_mcs": n_mcs,
                        "speedup": mp.cycles / md.cycles,
                        "access_cost_ratio": mp.avg_access_cost / max(md.avg_access_cost, 1e-9),
                        "net_bytes_ratio": mp.net_bytes / max(md.net_bytes, 1e-9),
                    }
                )
    return rows


def fig4_bottom_spec(
    workloads: Iterable[str] = ("pr", "dr", "st", "nw"),
    n_jobs: int = 4,
    *,
    cfg: Optional[SimConfig] = None,
    **kw,
) -> Sweep:
    """The canonical Fig. 4 (bottom) grid declaration."""
    return Sweep(
        name="fig4_bottom",
        axes={"workload": tuple(workloads), "scheme": ("page", "daemon"),
              "n_jobs": (n_jobs,)},
        base=cfg or SimConfig(),
        **_sweep_kw(kw),
    )


def fig4_bottom(
    workloads: Iterable[str] = ("pr", "dr", "st", "nw"),
    n_jobs: int = 4,
    *,
    cfg: Optional[SimConfig] = None,
    workers: Optional[int] = None,
    **kw,
) -> List[dict]:
    """Multiple concurrent jobs on one CC sharing the network and one MC."""
    sw = fig4_bottom_spec(workloads, n_jobs, cfg=cfg, **kw)
    res = run_sweep(sw, workers=workers)
    g = res.grid("workload", "scheme")
    rows = []
    for w in sw.axes["workload"]:
        mp, md = g[(w, "page")].metrics, g[(w, "daemon")].metrics
        rows.append(
            {
                "workload": w,
                "n_jobs": n_jobs,
                "speedup": mp.cycles / md.cycles,
                "access_cost_ratio": mp.avg_access_cost / max(md.avg_access_cost, 1e-9),
            }
        )
    return rows


DEFAULT_CC_MIXES = ("pr", "pr+st", "dr+st+pr+ml")


def fig5_scalability_spec(
    workload_mixes: Iterable[str] = DEFAULT_CC_MIXES,
    n_ccs_list: Iterable[int] = (1, 2, 4, 8),
    *,
    cfg: Optional[SimConfig] = None,
    **kw,
) -> Sweep:
    """The canonical multi-CC scalability grid (DESIGN.md §2.5): n_ccs
    compute complexes, each running a full application (a '+'-mix assigns
    workloads round-robin across CCs), contending for the shared MC
    downlink.  Shared by the API and benchmarks/fig5_scalability.py so the
    'fig5_scalability' BENCH_sim.json entry has one meaning."""
    axes = {
        "workload": tuple(workload_mixes),
        "n_ccs": tuple(n_ccs_list),
        "scheme": ("page", "daemon"),
    }
    return Sweep(name="fig5_scalability", axes=axes,
                 base=cfg or SimConfig(link_bw_frac=0.25), **_sweep_kw(kw))


def fig5_scalability(
    workload_mixes: Iterable[str] = DEFAULT_CC_MIXES,
    n_ccs_list: Iterable[int] = (1, 2, 4, 8),
    *,
    cfg: Optional[SimConfig] = None,
    workers: Optional[int] = None,
    **kw,
) -> List[dict]:
    """Daemon-vs-page speedup as a function of CC count: per (mix, n_ccs)
    rows plus the per-n_ccs geomean over the mixes."""
    sw = fig5_scalability_spec(workload_mixes, n_ccs_list, cfg=cfg, **kw)
    res = run_sweep(sw, workers=workers)
    g = res.grid("workload", "n_ccs", "scheme")
    rows = []
    for n_ccs in sw.axes["n_ccs"]:
        ratios = []
        for mix in sw.axes["workload"]:
            mp = g[(mix, n_ccs, "page")].metrics
            md = g[(mix, n_ccs, "daemon")].metrics
            ratios.append(mp.cycles / md.cycles)
            rows.append(
                {
                    "workload": mix,
                    "n_ccs": n_ccs,
                    "speedup": mp.cycles / md.cycles,
                    "access_cost_ratio": mp.avg_access_cost / max(md.avg_access_cost, 1e-9),
                    "net_bytes_ratio": mp.net_bytes / max(md.net_bytes, 1e-9),
                }
            )
        rows.append({"workload": "geomean", "n_ccs": n_ccs,
                     "speedup": geomean(ratios)})
    return rows


# the fig6 ablation grid: 'page' is the baseline, 'daemon' the full
# synergy; three ablations remove exactly one technique each (daemon_fifo:
# partitioning, daemon_fixed_gran: adaptive selection, daemon_nocomp:
# compression) and both_dualq keeps ONLY decoupled movement + partitioning
# (no selection unit, no throttle, no compression) — see policy.py
ABLATION_POLICIES = ("both_dualq", "daemon_fifo", "daemon_fixed_gran",
                     "daemon_nocomp")
# the paper suite plus the phase-changing source (where fixed granularity
# is wrong half the time — the adaptive-selection ablation's stress case)
ABLATION_WORKLOADS = DEFAULT_SUITE + ("ph",)


def fig6_ablation_spec(
    workloads: Iterable[str] = ABLATION_WORKLOADS,
    policies: Iterable[str] = ("page",) + ABLATION_POLICIES + ("daemon",),
    *,
    cfg: Optional[SimConfig] = None,
    **kw,
) -> Sweep:
    """The canonical ablation grid (DESIGN.md §2.6): policy x workload at
    the congested end of the paper's network range, where every technique's
    contribution is visible.  Shared by the API and
    benchmarks/fig6_ablation.py so the 'fig6_ablation' BENCH_sim.json entry
    has one meaning."""
    axes = {"workload": tuple(workloads), "scheme": tuple(policies)}
    return Sweep(name="fig6_ablation", axes=axes,
                 base=cfg or SimConfig(link_bw_frac=0.125), **_sweep_kw(kw))


def fig6_geomeans(res: SweepResult) -> List[dict]:
    """Per-policy speedups over 'page' from an executed fig6 grid: one row
    per non-baseline policy with the geomean across the grid's workloads
    plus the per-workload ratios.  The single source of the fig6 derived
    numbers — shared by :func:`fig6_ablation` and
    benchmarks/fig6_ablation.py so the CI-gated ledger values and the
    public API cannot diverge."""
    g = res.grid("workload", "scheme")
    rows = []
    for p in res.axes["scheme"]:
        if p == "page":
            continue
        ratios = {
            w: g[(w, "page")].metrics.cycles / g[(w, p)].metrics.cycles
            for w in res.axes["workload"]
        }
        rows.append({"policy": p, "geomean_vs_page": geomean(ratios.values()),
                     "per_workload": ratios})
    return rows


def fig6_ablation(
    workloads: Iterable[str] = ABLATION_WORKLOADS,
    policies: Iterable[str] = ("page",) + ABLATION_POLICIES + ("daemon",),
    *,
    cfg: Optional[SimConfig] = None,
    workers: Optional[int] = None,
    **kw,
) -> List[dict]:
    """The paper's ablation study: each technique contributes, the synergy
    dominates.  Per-policy rows carry the geomean speedup over 'page' across
    the workloads (plus per-workload ratios); every ablation should land
    strictly between 'page' (1.0) and 'daemon'."""
    sw = fig6_ablation_spec(workloads, policies, cfg=cfg, **kw)
    return fig6_geomeans(run_sweep(sw, workers=workers))


# the fig7 uplink grid (DESIGN.md §2.7): write-heavy workloads — sources
# whose migrated pages go back dirty, so the CC->MC reverse path actually
# carries writeback bulk ('wh' is the dedicated stress source)
UPLINK_WORKLOADS = ("wh", "st", "pf")
# uplink capacity as a fraction of the downlink: 1.0 = symmetric,
# 0.25 = the strongly-asymmetric fabrics the sweep is about
UPLINK_FRACS = (0.25, 0.5, 1.0)


def fig7_uplink_spec(
    workloads: Iterable[str] = UPLINK_WORKLOADS,
    uplink_fracs: Iterable[float] = UPLINK_FRACS,
    n_ccs_list: Iterable[int] = (1, 4),
    *,
    cfg: Optional[SimConfig] = None,
    **kw,
) -> Sweep:
    """The canonical uplink-contention grid (DESIGN.md §2.7): uplink/downlink
    asymmetry x write-heavy workload x CC count, page vs daemon.  The
    ``uplink_bw`` axis is absolute bytes/cycle derived from ``uplink_fracs``
    x the base config's ``link_bw``.  Shared by the API and
    benchmarks/fig7_uplink.py so the 'fig7_uplink' BENCH_sim.json entry has
    one meaning."""
    base = cfg or SimConfig()
    axes = {
        "workload": tuple(workloads),
        "uplink_bw": tuple(base.link_bw * f for f in uplink_fracs),
        "n_ccs": tuple(n_ccs_list),
        "scheme": ("page", "daemon"),
    }
    return Sweep(name="fig7_uplink", axes=axes, base=base, **_sweep_kw(kw))


def fig7_uplink(
    workloads: Iterable[str] = UPLINK_WORKLOADS,
    uplink_fracs: Iterable[float] = UPLINK_FRACS,
    n_ccs_list: Iterable[int] = (1, 4),
    *,
    cfg: Optional[SimConfig] = None,
    workers: Optional[int] = None,
    **kw,
) -> List[dict]:
    """Daemon-vs-page speedup as the uplink tightens: per (workload, n_ccs,
    uplink_bw) rows plus the per-uplink_bw geomean.  The paper's
    bandwidth-partitioning argument extended to the reverse path: under a
    FIFO uplink the page scheme's request packets queue behind 4 KiB
    writebacks, so daemon's advantage grows as ``uplink_bw`` drops."""
    sw = fig7_uplink_spec(workloads, uplink_fracs, n_ccs_list, cfg=cfg, **kw)
    res = run_sweep(sw, workers=workers)
    g = res.grid("workload", "uplink_bw", "n_ccs", "scheme")
    rows = []
    for ub in sw.axes["uplink_bw"]:
        ratios = []
        for w in sw.axes["workload"]:
            for n_ccs in sw.axes["n_ccs"]:
                mp = g[(w, ub, n_ccs, "page")].metrics
                md = g[(w, ub, n_ccs, "daemon")].metrics
                ratios.append(mp.cycles / md.cycles)
                rows.append(
                    {
                        "workload": w,
                        "uplink_bw": ub,
                        "n_ccs": n_ccs,
                        "speedup": mp.cycles / md.cycles,
                        "wb_page": mp.writebacks,
                        "uplink_bytes_ratio":
                            mp.uplink_bytes / max(md.uplink_bytes, 1e-9),
                    }
                )
        rows.append({"workload": "geomean", "uplink_bw": ub,
                     "speedup": geomean(ratios)})
    return rows


# the fig8 captured-kernel grid (DESIGN.md §2.8): the four Pallas-kernel
# streams captured by repro.capture, registered at import
KERNEL_WORKLOADS = ("fa_prefill", "fa_decode", "mamba_fwd", "bq_quant")
# page vs daemon plus the granularity extremes: pure line movement and
# daemon minus the selection unit (fixed granularity) — the ablations that
# show WHERE adaptive selection matters on real tiled streams
KERNEL_SCHEMES = ("page", "cacheline", "daemon_fixed_gran", "daemon")
KERNEL_BW_FRACS = (0.125, 0.5, 1.0)


def fig8_kernels_spec(
    workloads: Iterable[str] = KERNEL_WORKLOADS,
    schemes: Iterable[str] = KERNEL_SCHEMES,
    bw_fracs: Iterable[float] = KERNEL_BW_FRACS,
    *,
    cfg: Optional[SimConfig] = None,
    **kw,
) -> Sweep:
    """The canonical captured-kernel grid (DESIGN.md §2.8): captured Pallas
    workloads x movement policy x network bandwidth.  Shared by the API and
    benchmarks/fig8_kernels.py so the 'fig8_kernels' BENCH_sim.json entry
    has one meaning."""
    axes = {
        "workload": tuple(workloads),
        "link_bw_frac": tuple(bw_fracs),
        "scheme": tuple(schemes),
    }
    return Sweep(name="fig8_kernels", axes=axes, base=cfg or SimConfig(),
                 **_sweep_kw(kw))


def fig8_kernels(
    workloads: Iterable[str] = KERNEL_WORKLOADS,
    schemes: Iterable[str] = KERNEL_SCHEMES,
    bw_fracs: Iterable[float] = KERNEL_BW_FRACS,
    *,
    cfg: Optional[SimConfig] = None,
    workers: Optional[int] = None,
    **kw,
) -> List[dict]:
    """Movement policies on the kernels' own memory streams: per captured
    workload, the daemon-vs-page geomean across the bandwidth range plus
    per-(bw, scheme) speedups over page.  The headline: real tiled streams
    (dense spatial reuse inside a tile, abrupt inter-tile jumps) are
    page-friendly in a way no synthetic source in the suite is — daemon's
    selection unit correctly converges to page granularity (geomean ~1x
    where the synthetic suite gives ~3x) while pure line movement
    collapses."""
    sw = fig8_kernels_spec(workloads, schemes, bw_fracs, cfg=cfg, **kw)
    res = run_sweep(sw, workers=workers)
    g = res.grid("workload", "link_bw_frac", "scheme")
    rows = []
    for w in sw.axes["workload"]:
        ratios = []
        for bw in sw.axes["link_bw_frac"]:
            mp = g[(w, bw, "page")].metrics
            ratios.append(mp.cycles / g[(w, bw, "daemon")].metrics.cycles)
            for s in sw.axes["scheme"]:
                if s == "page":
                    continue
                ms = g[(w, bw, s)].metrics
                rows.append(
                    {
                        "workload": w,
                        "bw_frac": bw,
                        "scheme": s,
                        "speedup_vs_page": mp.cycles / ms.cycles,
                        "net_bytes_ratio": mp.net_bytes / max(ms.net_bytes, 1e-9),
                    }
                )
        rows.append({"workload": w, "scheme": "daemon",
                     "bw_frac": "geomean", "speedup_vs_page": geomean(ratios)})
    return rows


# the fig9 serving grid (DESIGN.md §2.9): request-level tail latency under
# open-loop load.  Two tenant profiles share the sweep machinery:
#   llm   — prefill = one fa_prefill burst, decode = fa_decode slices (the
#           captured Pallas streams; page-dense, so page granularity serves
#           tails well and daemon correctly converges to ~1x)
#   graph — a graph-analytics tenant issuing query requests (the paper's
#           'pr' source as both phases; sparse irregular gathers, where
#           page-granularity tails collapse under load and daemon's
#           adaptive movement wins p99 by >10x)
# The pair is the request-level restatement of the paper's robustness
# claim "across application characteristics".
SERVING_TENANTS = {
    "llm": ("fa_prefill", "fa_decode"),
    "graph": ("pr", "pr"),
}
SERVING_LOADS = (8.0, 16.0, 24.0)  # offered load, requests per Mcycle
SERVING_ROUTERS = ("round_robin", "least_loaded", "disagg_prefill")


def fig9_serving_spec(
    loads: Iterable[float] = SERVING_LOADS,
    routers: Iterable[str] = SERVING_ROUTERS,
    schemes: Iterable[str] = ("page", "daemon"),
    *,
    tenant: str = "llm",
    cfg: Optional[SimConfig] = None,
    n_requests: int = 48,
    prefill_accesses: int = 1024,
    decode_steps: int = 4,
    decode_accesses: int = 256,
    **kw,
) -> Sweep:
    """The canonical serving grid (DESIGN.md §2.9) for one tenant profile:
    offered load x router policy x scheme, on a 4-CC node with a congested
    downlink (1/8 bus bandwidth) and an asymmetric contended uplink.  The
    sweep name is ``fig9_serving_<tenant>``; shared by the API and
    benchmarks/fig9_serving.py so each BENCH_sim.json entry has one
    meaning."""
    if tenant not in SERVING_TENANTS:
        raise KeyError(f"unknown serving tenant {tenant!r}; "
                       f"choose from {sorted(SERVING_TENANTS)}")
    pre, dec = SERVING_TENANTS[tenant]
    base = cfg or SimConfig(n_ccs=4, link_bw_frac=0.125, uplink_bw=1.0)
    base = base.with_(
        prefill_workload=pre, decode_workload=dec, n_requests=n_requests,
        prefill_accesses=prefill_accesses, decode_steps=decode_steps,
        decode_accesses=decode_accesses)
    axes = {
        "offered_load": tuple(loads),
        "serving_router": tuple(routers),
        "scheme": tuple(schemes),
    }
    return Sweep(name=f"fig9_serving_{tenant}", axes=axes, base=base,
                 **_sweep_kw(kw))


def fig9_tails(res: SweepResult, tenant: str) -> tuple:
    """Derived tail statistics from an executed fig9 grid: per (load,
    router) rows with p50/p99/goodput for page and daemon, a per-load
    geomean row, and the gated derived keys
    ``daemon_vs_page_p99@load=<L>:tenant=<T>`` (geomean over routers of
    page_p99/daemon_p99 — >1 means daemon serves the tail better).  The
    single source of the fig9 derived numbers — shared by
    :func:`fig9_serving` and benchmarks/fig9_serving.py so the CI-gated
    ledger values and the public API cannot diverge."""
    g = res.grid("offered_load", "serving_router", "scheme")
    rows: List[dict] = []
    derived: Dict[str, float] = {}
    for load in res.axes["offered_load"]:
        ratios = []
        for router in res.axes["serving_router"]:
            mp = g[(load, router, "page")].metrics
            md = g[(load, router, "daemon")].metrics
            ratio = mp.request_p99 / max(md.request_p99, 1e-9)
            ratios.append(ratio)
            rows.append(
                {
                    "tenant": tenant,
                    "offered_load": load,
                    "router": router,
                    "p99_ratio": ratio,
                    "page_p99": mp.request_p99,
                    "daemon_p99": md.request_p99,
                    "page_p50": mp.request_p50,
                    "daemon_p50": md.request_p50,
                    "page_goodput": mp.goodput,
                    "daemon_goodput": md.goodput,
                    "completed": (mp.requests_completed,
                                  md.requests_completed),
                }
            )
        gm = geomean(ratios)
        derived[f"daemon_vs_page_p99@load={load:g}:tenant={tenant}"] = gm
        rows.append({"tenant": tenant, "offered_load": load,
                     "router": "geomean", "p99_ratio": gm})
    return rows, derived


def fig9_serving(
    loads: Iterable[float] = SERVING_LOADS,
    routers: Iterable[str] = SERVING_ROUTERS,
    schemes: Iterable[str] = ("page", "daemon"),
    *,
    tenants: Iterable[str] = ("llm", "graph"),
    cfg: Optional[SimConfig] = None,
    workers: Optional[int] = None,
    n_requests: int = 48,
    prefill_accesses: int = 1024,
    decode_steps: int = 4,
    decode_accesses: int = 256,
    **kw,
) -> List[dict]:
    """Request tail latency under open-loop load: one sweep per tenant
    profile, rows per (tenant, load, router) with page/daemon p50/p99/
    goodput plus per-load p99-ratio geomeans.  The headline mirrors fig8's
    at the request level: on the captured LLM kernel streams page
    granularity already serves tails well (ratios ~1x), while the sparse
    graph tenant's p99 collapses under page-granularity movement and
    daemon wins the tail by an order of magnitude."""
    rows: List[dict] = []
    for tenant in tenants:
        sw = fig9_serving_spec(
            loads, routers, schemes, tenant=tenant, cfg=cfg,
            n_requests=n_requests, prefill_accesses=prefill_accesses,
            decode_steps=decode_steps, decode_accesses=decode_accesses,
            **dict(kw))
        t_rows, _ = fig9_tails(run_sweep(sw, workers=workers), tenant)
        rows += t_rows
    return rows


# the fig10 topology grid (DESIGN.md §2.11): routed fabrics between the
# compute and memory pools.  'direct' is the legacy flat per-MC link bundle
# expressed as a 1-hop fabric (bit-identical metrics); 'single_switch' folds
# every flow through one crossbar; 'two_tier' adds leaf/spine trunks whose
# capacity shrinks with the oversubscription ratio
TOPOLOGIES = ("direct", "single_switch", "two_tier")
# pointer-chase (latency-bound lines) vs streaming (page-friendly bulk):
# the pair where fabric partitioning matters most and least
TOPOLOGY_WORKLOADS = ("pr", "st")
# trunk oversubscription ratios for the two_tier grid: 1.0 = non-blocking
OVERSUBS = (1.0, 2.0, 4.0)


def fig10_topology_spec(
    topologies: Iterable[str] = TOPOLOGIES,
    workloads: Iterable[str] = TOPOLOGY_WORKLOADS,
    n_ccs_list: Iterable[int] = (1, 4),
    *,
    cfg: Optional[SimConfig] = None,
    **kw,
) -> Sweep:
    """The canonical topology grid (DESIGN.md §2.11): fabric shape x
    workload x CC count, page vs daemon, at the congested end of the
    paper's network range.  Shared by the API and
    benchmarks/fig10_topology.py so the 'fig10_topology' BENCH_sim.json
    entry has one meaning."""
    axes = {
        "workload": tuple(workloads),
        "topology": tuple(topologies),
        "n_ccs": tuple(n_ccs_list),
        "scheme": ("page", "daemon"),
    }
    return Sweep(name="fig10_topology", axes=axes,
                 base=cfg or SimConfig(link_bw_frac=0.25), **_sweep_kw(kw))


def fig10_oversub_spec(
    oversubs: Iterable[float] = OVERSUBS,
    workloads: Iterable[str] = TOPOLOGY_WORKLOADS,
    n_ccs_list: Iterable[int] = (1, 4),
    *,
    cfg: Optional[SimConfig] = None,
    **kw,
) -> Sweep:
    """The canonical oversubscription grid (DESIGN.md §2.11): the two_tier
    fabric's leaf/spine trunks tightened from non-blocking (1.0) to 4:1,
    page vs daemon.  Daemon's dual-queue partitioning rides every hop, so
    its advantage must grow monotonically as the trunks congest — the
    fabric-level restatement of the paper's Fig. 4 bandwidth sweep."""
    base = (cfg or SimConfig(link_bw_frac=0.25)).with_(topology="two_tier")
    axes = {
        "workload": tuple(workloads),
        "oversub": tuple(oversubs),
        "n_ccs": tuple(n_ccs_list),
        "scheme": ("page", "daemon"),
    }
    return Sweep(name="fig10_oversub", axes=axes, base=base, **_sweep_kw(kw))


def fig10_topology(
    topologies: Iterable[str] = TOPOLOGIES,
    oversubs: Iterable[float] = OVERSUBS,
    workloads: Iterable[str] = TOPOLOGY_WORKLOADS,
    n_ccs_list: Iterable[int] = (1, 4),
    *,
    cfg: Optional[SimConfig] = None,
    workers: Optional[int] = None,
    **kw,
) -> List[dict]:
    """Daemon-vs-page speedup across fabric shapes and trunk
    oversubscription: per-cell rows plus a per-topology geomean and a
    per-oversub geomean (two_tier).  The headline: page's 4 KiB transfers
    monopolise every shared trunk they cross, so the deeper and more
    oversubscribed the fabric, the more daemon's end-to-end dual-queue
    partitioning is worth."""
    rows: List[dict] = []
    sw = fig10_topology_spec(topologies, workloads, n_ccs_list, cfg=cfg,
                             **dict(kw))
    g = run_sweep(sw, workers=workers).grid(
        "workload", "topology", "n_ccs", "scheme")
    for topo in sw.axes["topology"]:
        ratios = []
        for w in sw.axes["workload"]:
            for n_ccs in sw.axes["n_ccs"]:
                mp = g[(w, topo, n_ccs, "page")].metrics
                md = g[(w, topo, n_ccs, "daemon")].metrics
                ratios.append(mp.cycles / md.cycles)
                rows.append(
                    {
                        "workload": w,
                        "topology": topo,
                        "n_ccs": n_ccs,
                        "speedup": mp.cycles / md.cycles,
                        "net_bytes_ratio": mp.net_bytes / max(md.net_bytes, 1e-9),
                    }
                )
        rows.append({"workload": "geomean", "topology": topo,
                     "speedup": geomean(ratios)})
    so = fig10_oversub_spec(oversubs, workloads, n_ccs_list, cfg=cfg,
                            **dict(kw))
    go = run_sweep(so, workers=workers).grid(
        "workload", "oversub", "n_ccs", "scheme")
    for o in so.axes["oversub"]:
        ratios = []
        for w in so.axes["workload"]:
            for n_ccs in so.axes["n_ccs"]:
                mp = go[(w, o, n_ccs, "page")].metrics
                md = go[(w, o, n_ccs, "daemon")].metrics
                ratios.append(mp.cycles / md.cycles)
                rows.append(
                    {
                        "workload": w,
                        "topology": "two_tier",
                        "oversub": o,
                        "n_ccs": n_ccs,
                        "speedup": mp.cycles / md.cycles,
                    }
                )
        rows.append({"workload": "geomean", "topology": "two_tier",
                     "oversub": o, "speedup": geomean(ratios)})
    return rows


# the fig11 controller grids (DESIGN.md §2.12): the registered movement
# controllers compared head-to-head on the three grids where the selection
# unit's decisions bind — the synthetic ablation suite, the asymmetric
# uplink grid, and the captured Pallas-kernel streams
CONTROLLERS = ("fixed", "adaptive", "tuned")
# fig11 compares the controllers inside the daemon scheme against the page
# baseline; the ablation policies are fig6's concern, not fig11's
CONTROLLER_SCHEMES = ("page", "daemon")


def fig11_ablation_spec(
    workloads: Iterable[str] = ABLATION_WORKLOADS,
    controllers: Iterable[str] = CONTROLLERS,
    *,
    cfg: Optional[SimConfig] = None,
    **kw,
) -> Sweep:
    """Controller x workload on fig6's congested synthetic grid (DESIGN.md
    §2.12): the guardrail half of fig11 — a controller that loses to
    'fixed' here trades away the paper's headline speedups.  Shared by the
    API and benchmarks/fig11_controllers.py so the
    'daemon_vs_page_geomean@ctrl=*' BENCH_sim.json entries have one
    meaning."""
    axes = {
        "workload": tuple(workloads),
        "controller": tuple(controllers),
        "scheme": CONTROLLER_SCHEMES,
    }
    return Sweep(name="fig11_ablation", axes=axes,
                 base=cfg or SimConfig(link_bw_frac=0.125), **_sweep_kw(kw))


def fig11_uplink_spec(
    workloads: Iterable[str] = UPLINK_WORKLOADS,
    uplink_fracs: Iterable[float] = UPLINK_FRACS,
    controllers: Iterable[str] = CONTROLLERS,
    *,
    cfg: Optional[SimConfig] = None,
    **kw,
) -> Sweep:
    """Controller x uplink asymmetry on fig7's write-heavy grid: where the
    adaptive controller's uplink-backlog signal (compress writebacks before
    the reverse path saturates) can actually pay."""
    base = cfg or SimConfig()
    axes = {
        "workload": tuple(workloads),
        "uplink_bw": tuple(base.link_bw * f for f in uplink_fracs),
        "controller": tuple(controllers),
        "scheme": CONTROLLER_SCHEMES,
    }
    return Sweep(name="fig11_uplink", axes=axes, base=base, **_sweep_kw(kw))


def fig11_kernels_spec(
    workloads: Iterable[str] = KERNEL_WORKLOADS,
    bw_fracs: Iterable[float] = KERNEL_BW_FRACS,
    controllers: Iterable[str] = CONTROLLERS,
    *,
    cfg: Optional[SimConfig] = None,
    **kw,
) -> Sweep:
    """Controller x bandwidth on fig8's captured Pallas-kernel streams: the
    upside half of fig11 — the page-dense phases where 'fixed' keeps racing
    lines it always loses and an observing controller can back off."""
    axes = {
        "workload": tuple(workloads),
        "link_bw_frac": tuple(bw_fracs),
        "controller": tuple(controllers),
        "scheme": CONTROLLER_SCHEMES,
    }
    return Sweep(name="fig11_kernels", axes=axes, base=cfg or SimConfig(),
                 **_sweep_kw(kw))


def fig11_geomeans(
    ab: SweepResult, up: SweepResult, kn: SweepResult,
) -> Dict[str, float]:
    """Derived daemon-vs-page geomeans per controller from executed fig11
    grids — the single source of the 'daemon_vs_page_geomean@ctrl=*' ledger
    keys (gated by benchmarks/check_bench.py), shared by
    :func:`fig11_controllers` and benchmarks/fig11_controllers.py.

    Per controller ``c``: ``@ctrl={c}`` (synthetic ablation suite),
    ``@ctrl={c}:grid=uplink`` (write-heavy uplink grid), and one
    ``@ctrl={c}:kernel={w}`` per captured kernel (geomean across the
    bandwidth range).  'fixed' rows must reproduce the controller-free
    grids bit-for-bit; 'adaptive' must clear the fixed kernel baselines on
    at least one captured stream without giving back the synthetics."""
    out: Dict[str, float] = {}
    ga = ab.grid("workload", "controller", "scheme")
    gu = up.grid("workload", "uplink_bw", "controller", "scheme")
    gk = kn.grid("workload", "link_bw_frac", "controller", "scheme")
    for c in ab.axes["controller"]:
        out[f"daemon_vs_page_geomean@ctrl={c}"] = geomean(
            ga[(w, c, "page")].metrics.cycles
            / ga[(w, c, "daemon")].metrics.cycles
            for w in ab.axes["workload"])
        out[f"daemon_vs_page_geomean@ctrl={c}:grid=uplink"] = geomean(
            gu[(w, ub, c, "page")].metrics.cycles
            / gu[(w, ub, c, "daemon")].metrics.cycles
            for w in up.axes["workload"] for ub in up.axes["uplink_bw"])
        for w in kn.axes["workload"]:
            out[f"daemon_vs_page_geomean@ctrl={c}:kernel={w}"] = geomean(
                gk[(w, bw, c, "page")].metrics.cycles
                / gk[(w, bw, c, "daemon")].metrics.cycles
                for bw in kn.axes["link_bw_frac"])
    return out


def fig11_controllers(
    controllers: Iterable[str] = CONTROLLERS,
    *,
    cfg: Optional[SimConfig] = None,
    workers: Optional[int] = None,
    **kw,
) -> Dict[str, float]:
    """Head-to-head movement controllers (DESIGN.md §2.12): daemon-vs-page
    geomeans per controller over the synthetic ablation suite, the
    asymmetric-uplink grid, and the captured kernel streams.  The headline:
    'fixed' reproduces every legacy number exactly, 'adaptive' buys back
    the kernel traces (where fixed granularity racing loses) at <5% cost on
    the synthetics, 'tuned' shows the offline-fitted ceiling."""
    kw2 = dict(kw)
    ab = run_sweep(fig11_ablation_spec(controllers=controllers, cfg=cfg,
                                       **dict(kw2)), workers=workers)
    up = run_sweep(fig11_uplink_spec(controllers=controllers, cfg=cfg,
                                     **dict(kw2)), workers=workers)
    kn = run_sweep(fig11_kernels_spec(controllers=controllers, cfg=cfg,
                                      **dict(kw2)), workers=workers)
    return fig11_geomeans(ab, up, kn)


# the fig12 memory-pool grids (DESIGN.md §2.13): finite per-MC capacity and
# first-class placement policies under multi-tenant '+'-mixes — the scenario
# family the paper never swept (it models remote memory as an infinite
# passive address space)
MEM_PLACEMENTS = ("page", "first_touch", "capacity_aware")
# pages per MC: None = legacy infinite pool (the bit-identical baseline),
# 512 = mild pressure (spills begin), 128 = heavy churn (eviction-dominated)
MEM_CAPACITIES = (None, 512, 128)
# fig12 compares daemon against the page baseline under capacity pressure;
# the controller comparison is fig11's concern
MEM_SCHEMES = ("page", "daemon")


def _mem_tag(cap: Optional[int]) -> str:
    return "inf" if cap is None else str(cap)


def fig12_memside_spec(
    workload_mixes: Iterable[str] = DEFAULT_CC_MIXES,
    placements: Iterable[str] = MEM_PLACEMENTS,
    capacities: Iterable[Optional[int]] = MEM_CAPACITIES,
    *,
    cfg: Optional[SimConfig] = None,
    **kw,
) -> Sweep:
    """Tenant mix x placement x capacity pressure x scheme (DESIGN.md
    §2.13): four CCs run skewed '+'-mixes against four finite MCs, so
    placement decides which modules fill, spills detour across the ring,
    and cold residents churn out under pressure.  Shared by the API and
    benchmarks/fig12_memside.py so the 'daemon_vs_page_geomean@mem=*'
    BENCH_sim.json entries have one meaning.  Every cell is batch-engine
    covered (§2.13 cells run on the lockstep core)."""
    axes = {
        "workload": tuple(workload_mixes),
        "mc_interleave": tuple(placements),
        "mc_capacity_pages": tuple(capacities),
        "scheme": MEM_SCHEMES,
    }
    base = cfg or SimConfig(n_ccs=4, n_mcs=4, link_bw_frac=0.25)
    return Sweep(name="fig12_memside", axes=axes, base=base, **_sweep_kw(kw))


def fig12_geomeans(res: SweepResult) -> Dict[str, float]:
    """Derived daemon-vs-page geomeans per (capacity, placement) cell of an
    executed fig12 grid — the single source of the
    'daemon_vs_page_geomean@mem={inf|<cap>}:place=<p>' ledger keys (gated
    by benchmarks/check_bench.py).  The headline question: does DaeMon's
    decoupled-granularity advantage survive when page migration also
    triggers capacity evictions?  '@mem=inf' rows must reproduce the
    infinite-pool behaviour of the legacy grids."""
    out: Dict[str, float] = {}
    g = res.grid("workload", "mc_interleave", "mc_capacity_pages", "scheme")
    for cap in res.axes["mc_capacity_pages"]:
        for pl in res.axes["mc_interleave"]:
            out[f"daemon_vs_page_geomean@mem={_mem_tag(cap)}:place={pl}"] = \
                geomean(
                    g[(w, pl, cap, "page")].metrics.cycles
                    / g[(w, pl, cap, "daemon")].metrics.cycles
                    for w in res.axes["workload"])
    return out


def fig12_memside(
    *,
    cfg: Optional[SimConfig] = None,
    workers: Optional[int] = None,
    engine: Optional[str] = None,
    **kw,
) -> Dict[str, float]:
    """Memory-pool grid (DESIGN.md §2.13): daemon-vs-page geomeans per
    (capacity pressure, placement policy) over the multi-tenant mixes."""
    res = run_sweep(fig12_memside_spec(cfg=cfg, **kw), workers=workers,
                    engine=engine)
    return fig12_geomeans(res)


def paper_claims(
    bw_fracs: Iterable[float] = (0.25, 0.125),
    *,
    workloads: Iterable[str] = DEFAULT_WORKLOADS,
    workers: Optional[int] = None,
    n_jobs: int = 1,
    **kw,
) -> dict:
    """Geomean daemon-vs-page improvements over the workload suite across the
    paper's network operating range — the quantities the paper reports as
    3.06x (access-cost reduction) and 2.39x (performance)."""
    axes = {
        "link_bw_frac": tuple(bw_fracs),
        "workload": tuple(workloads),
        "scheme": ("page", "daemon"),
    }
    if n_jobs != 1:
        axes["n_jobs"] = (n_jobs,)
    sw = Sweep(name="paper_claims", axes=axes, **_sweep_kw(kw))
    res = run_sweep(sw, workers=workers)
    perf, cost, per_bw = [], [], {}
    for bw in sw.axes["link_bw_frac"]:
        rows = res.filter(link_bw_frac=bw)
        p = scheme_ratio(rows, metric="cycles")
        c = scheme_ratio(rows, metric="avg_access_cost")
        per_bw[bw] = {
            "perf": geomean(p.values()),
            "cost": geomean(c.values()),
            "per_workload": {
                dict(k)["workload"]: v for k, v in p.items()
            },
        }
        perf += list(p.values())
        cost += list(c.values())
    return {
        "perf_speedup_geomean": geomean(perf),
        "access_cost_reduction_geomean": geomean(cost),
        "per_bw": per_bw,
    }
