"""Workload trace sources (DESIGN.md §2.4) behind a ``@register_workload``
registry.

A trace is three parallel numpy arrays:
    gaps:  int64 compute cycles between consecutive memory accesses
    addrs: int64 byte addresses
    writes: bool

Every source is a :class:`WorkloadSpec` carrying its own metadata — the
generator, its page compressibility (drives the link-compression model; was
the ``COMPRESSIBILITY`` side-table), and a description.  Built-ins are
synthetic generators modeled on the paper's evaluation domains (graph
processing, HPC, data analytics, bioinformatics, ML), spanning the locality
spectrum from pointer-chase (``dr``) to streaming (``st``), plus a
phase-changing source (``ph``) and ``.npz`` trace replay
(:func:`register_trace_file`; any workload name ending in ``.npz``
auto-registers as a replay of that file).  All registered names are valid
inside '+'-separated multi-CC mixes.  Define your own in ~5 lines:

    from repro.core.sim import register_workload, run_one

    @register_workload("zig", compressibility=2.5,
                       description="strided zig-zag scan")
    def zigzag(seed, footprint, n):
        import numpy as np
        addrs = (np.arange(n) * 192) % footprint
        return (np.full(n, 20, np.int64), addrs.astype(np.int64),
                np.zeros(n, bool))
    run_one("zig", "daemon")

All generators are deterministic (seeded) and parameterized by footprint so
the local-memory fraction is meaningful.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import numpy as np

Trace = Tuple[np.ndarray, np.ndarray, np.ndarray]

DEFAULT_FOOTPRINT = 32 << 20  # 32 MiB
DEFAULT_ACCESSES = 120_000

DEFAULT_COMPRESSIBILITY = 2.0  # for direct trace injection (workload="")

# the paper's eight-workload evaluation suite, in figure order (the default
# grid of fig2/paper_claims — deliberately NOT "every registered workload",
# so registering a new source never silently changes committed grids)
DEFAULT_SUITE = ("pr", "bf", "ts", "nw", "dr", "pf", "st", "ml")


@dataclass(frozen=True)
class WorkloadSpec:
    """One registered trace source: generator + its own metadata.

    ``compressibility`` is the mean page compression ratio for the
    link-compression model (ratio ~ N(mean, 0.15*mean), >= 1): graphs/int
    data compress well; float/ML data less [paper §3(III)].  It may also
    be a zero-arg callable resolved (and cached by the callable) on first
    use — measured-from-data sources (repro.capture) defer the measurement
    so registration stays import-cheap; resolve via
    :func:`compressibility_of`, never by reading the field directly.
    """

    name: str
    generator: Callable[[int, int, int], Trace]
    compressibility: object = DEFAULT_COMPRESSIBILITY  # float | () -> float
    description: str = ""

    def trace(self, *, seed: int = 0, footprint: int = DEFAULT_FOOTPRINT,
              n: int = DEFAULT_ACCESSES) -> Trace:
        return self.generator(seed, footprint, n)

    # legacy call style: WORKLOADS[name](seed, footprint, n)
    def __call__(self, seed: int, footprint: int, n: int) -> Trace:
        return self.generator(seed, footprint, n)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

# public view (name -> spec), kept under the legacy name so existing
# `tuple(WORKLOADS)` / `"pr" in WORKLOADS` call sites keep working
WORKLOADS: Dict[str, WorkloadSpec] = {}


def register_workload(name: str, *, compressibility: float = DEFAULT_COMPRESSIBILITY,
                      description: str = "", overwrite: bool = False):
    """Decorator registering ``fn(seed, footprint, n) -> Trace`` under
    ``name`` with its metadata.  Duplicate names raise unless
    ``overwrite=True``."""

    def deco(fn: Callable[[int, int, int], Trace]):
        _register(WorkloadSpec(
            name=name, generator=fn, compressibility=float(compressibility),
            description=description or (fn.__doc__ or "").strip().split("\n")[0],
        ), overwrite=overwrite)
        return fn

    return deco


def _register(spec: WorkloadSpec, *, overwrite: bool = False) -> WorkloadSpec:
    if spec.name in WORKLOADS and not overwrite:
        raise ValueError(
            f"workload {spec.name!r} already registered "
            f"(pass overwrite=True to replace)")
    if "+" in spec.name:
        raise ValueError(f"workload name {spec.name!r} may not contain '+' "
                         f"(reserved for multi-CC mixes)")
    WORKLOADS[spec.name] = spec
    return spec


def unregister_workload(name: str) -> None:
    """Remove a registered workload (tests / interactive experimentation)."""
    WORKLOADS.pop(name, None)


def get_workload(name: str) -> WorkloadSpec:
    """Resolve one workload name (NOT a '+' mix); unknown names fail fast
    listing the registered choices.  Names ending in ``.npz`` auto-register
    as trace replays of that file."""
    spec = WORKLOADS.get(name)
    if spec is None and name.endswith(".npz"):
        return register_trace_file(name)
    if spec is None:
        raise KeyError(
            f"unknown workload {name!r}; registered workloads: "
            f"{', '.join(available_workloads())} (or a path to a .npz trace)")
    return spec


def available_workloads() -> Tuple[str, ...]:
    return tuple(WORKLOADS)


def compressibility_of(name: str) -> float:
    """Per-workload mean page compression ratio; the empty name (direct
    trace injection into ``simulate``) gets the neutral default.  Callable
    (lazily measured) compressibilities are resolved here."""
    if not name:
        return DEFAULT_COMPRESSIBILITY
    c = get_workload(name).compressibility
    return float(c() if callable(c) else c)


def generate(name: str, *, seed: int = 0, footprint: int = DEFAULT_FOOTPRINT,
             n: int = DEFAULT_ACCESSES) -> Trace:
    return get_workload(name).trace(seed=seed, footprint=footprint, n=n)


# --------------------------------------------------------------------------
# .npz trace replay
# --------------------------------------------------------------------------


def replay_slice(trace: Trace, seed: int, n: int) -> Trace:
    """The replay view shared by ``.npz`` trace files and captured kernel
    workloads (repro.capture): ``n`` truncates or tiles the trace and
    ``seed`` rotates the starting offset so multiple threads replay the
    same trace out of phase rather than in lockstep.  A window larger than
    the trace wraps (tiles); ``n < 1`` or an empty trace fails fast — a
    zero-length replay window is always a caller bug (e.g. a serving phase
    with no accesses), and silently returning empty arrays would shift the
    replay phase of every later slice."""
    gaps, addrs, writes = trace
    total = len(addrs)
    if n < 1 or total == 0:
        raise ValueError(
            f"replay_slice: need n >= 1 and a non-empty trace "
            f"(got n={n}, trace length {total})")
    roll = (seed * 9973) % total
    idx = (np.arange(n, dtype=np.int64) + roll) % total
    return gaps[idx], addrs[idx], writes[idx]


def save_trace(path: str, trace: Trace,
               compressibility: float = DEFAULT_COMPRESSIBILITY) -> None:
    """Persist a trace (gaps, addrs, writes) + its compressibility metadata
    as a ``.npz`` file replayable via :func:`register_trace_file` (or just by
    using the path as a workload name)."""
    gaps, addrs, writes = trace
    np.savez(path, gaps=np.asarray(gaps, np.int64),
             addrs=np.asarray(addrs, np.int64),
             writes=np.asarray(writes, bool),
             compressibility=np.float64(compressibility))


def register_trace_file(path: str, name: Optional[str] = None, *,
                        overwrite: bool = False) -> WorkloadSpec:
    """Register a ``.npz`` trace file (written by :func:`save_trace`) as a
    workload.  ``name`` defaults to the path itself, so the same string
    works as a workload name everywhere (including '+' mixes).

    Replay is deterministic: the file's footprint is authoritative (the
    ``footprint`` argument is ignored), ``n`` truncates or tiles the trace,
    and ``seed`` rotates the starting offset so multiple threads replay the
    same trace out of phase rather than in lockstep.
    """
    name = name or path
    if name in WORKLOADS:
        if overwrite:
            del WORKLOADS[name]
        else:
            return WORKLOADS[name]
    if not os.path.exists(path):
        raise FileNotFoundError(f"trace file {path!r} does not exist")
    with np.load(path) as f:
        missing = {"gaps", "addrs", "writes"} - set(f.files)
        if missing:
            raise ValueError(f"trace file {path!r} lacks arrays {sorted(missing)}")
        gaps = np.asarray(f["gaps"], np.int64)
        addrs = np.asarray(f["addrs"], np.int64)
        writes = np.asarray(f["writes"], bool)
        comp = float(f["compressibility"]) if "compressibility" in f.files \
            else DEFAULT_COMPRESSIBILITY
    if not (len(gaps) == len(addrs) == len(writes)) or len(gaps) == 0:
        raise ValueError(f"trace file {path!r}: arrays must be equal-length "
                         f"and non-empty")

    def replay(seed: int, footprint: int, n: int) -> Trace:
        return replay_slice((gaps, addrs, writes), seed, n)

    return _register(WorkloadSpec(
        name=name, generator=replay, compressibility=comp,
        description=f"replay of {path} ({len(addrs)} accesses)",
    ), overwrite=overwrite)


# --------------------------------------------------------------------------
# built-in synthetic generators
# --------------------------------------------------------------------------


def _mk(gaps, addrs, writes, footprint) -> Trace:
    return (
        np.asarray(gaps, np.int64),
        np.asarray(addrs, np.int64) % footprint,
        np.asarray(writes, bool),
    )


@register_workload("dr", compressibility=1.8)
def ptr_chase(seed: int, footprint: int, n: int) -> Trace:
    """dr (delaunay-refinement-like): random cavity walks — jump to a random
    element record, touch 3 consecutive lines, hop.  Low page locality with
    small bursts (capacity-intensive irregular, the paper's dominant class)."""
    rng = np.random.default_rng(seed)
    run = 3  # lines per visited record
    n_runs = n // run + 1
    starts = rng.integers(0, footprint, n_runs) & ~63
    offs = (np.arange(run) * 64)[None, :]
    addrs = (starts[:, None] + offs).reshape(-1)[:n]
    writes = rng.random(n) < 0.2
    gaps = rng.integers(15, 40, n)
    return _mk(gaps, addrs, writes, footprint)


@register_workload("pr", compressibility=3.0)
def pagerank(seed: int, footprint: int, n: int) -> Trace:
    """pr: irregular graph access —near-uniform random edge/vertex loads with a
    thin sequential rank stream.  LOW page locality: the paper's line-friendly
    class (moving 4 KiB to use 64 B)."""
    rng = np.random.default_rng(seed)
    rand = rng.integers(0, footprint * 7 // 8, n) & ~63
    seq = (np.arange(n) * 64) % (footprint // 8) + footprint * 7 // 8
    addrs = np.where(rng.random(n) < 0.85, rand, seq)
    writes = rng.random(n) < 0.15
    gaps = rng.integers(15, 40, n)
    return _mk(gaps, addrs, writes, footprint)


@register_workload("bf", compressibility=3.0)
def bfs(seed: int, footprint: int, n: int) -> Trace:
    """bf: frontier bursts — short sequential runs at random page locations."""
    rng = np.random.default_rng(seed)
    run = 8
    n_runs = n // run
    starts = rng.integers(0, footprint, n_runs) & ~63
    offs = (np.arange(run) * 64)[None, :]
    addrs = (starts[:, None] + offs).reshape(-1)[:n]
    gaps = rng.integers(10, 30, n)
    return _mk(gaps, addrs, np.zeros(n, bool), footprint)


@register_workload("st", compressibility=4.0)
def streaming(seed: int, footprint: int, n: int) -> Trace:
    """st (data-analytics scan): fully sequential — maximal page locality."""
    rng = np.random.default_rng(seed)
    addrs = (np.arange(n) * 64) % footprint
    gaps = rng.integers(8, 20, n)
    writes = rng.random(n) < 0.1
    return _mk(gaps, addrs, writes, footprint)


@register_workload("nw", compressibility=2.5)
def nw(seed: int, footprint: int, n: int) -> Trace:
    """nw (bioinformatics DP): anti-diagonal wavefront — consecutive cells
    stride by ~a row, touching ONE line per page before moving on.  The
    paper's other line-friendly workload."""
    rng = np.random.default_rng(seed)
    row_bytes = 1 << 14  # 16 KiB rows: stride skips 4 pages per step
    i = np.arange(n, dtype=np.int64)
    addrs = (i * (row_bytes + 64)) % footprint
    writes = rng.random(n) < 0.3
    gaps = rng.integers(12, 30, n)
    return _mk(gaps, addrs, writes, footprint)


@register_workload("ts", compressibility=2.0)
def hash_join(seed: int, footprint: int, n: int) -> Trace:
    """ts (analytics): sequential probe stream + random hash-table lookups."""
    rng = np.random.default_rng(seed)
    seq = (np.arange(n) * 64) % (footprint // 2)
    ht = rng.integers(footprint // 2, footprint, n) & ~63
    addrs = np.where(np.arange(n) % 2 == 0, seq, ht)
    gaps = rng.integers(10, 25, n)
    return _mk(gaps, addrs, np.zeros(n, bool), footprint)


@register_workload("ml", compressibility=1.5)
def kmeans(seed: int, footprint: int, n: int) -> Trace:
    """ml (embedding/recsys): random embedding-row gathers (2 lines each)
    plus a thin sequential activation stream — sparse, capacity-intensive."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, footprint * 7 // 8 >> 7, n) << 7  # 128B rows
    row_burst = rows + (np.arange(n) % 2) * 64
    seq = (np.arange(n) * 64) % (footprint // 8) + footprint * 7 // 8
    addrs = np.where(rng.random(n) < 0.85, row_burst, seq)
    gaps = rng.integers(15, 35, n)
    return _mk(gaps, addrs, np.zeros(n, bool), footprint)


@register_workload("pf", compressibility=2.2)
def pf(seed: int, footprint: int, n: int) -> Trace:
    """pf (particle filter): sequential weight scan (page-friendly phase)
    interleaved with random ancestor gathers (resampling) — mixed locality."""
    rng = np.random.default_rng(seed)
    i = np.arange(n)
    seq = ((i // 128) * 4096 + (i % 128) * 32) % (footprint // 2)
    rnd = (rng.integers(footprint // 2, footprint, n) & ~63)
    addrs = np.where(rng.random(n) < 0.65, seq, rnd)
    gaps = rng.integers(8, 18, n)
    writes = rng.random(n) < 0.2
    return _mk(gaps, addrs, writes, footprint)


@register_workload("wh", compressibility=3.2)
def write_heavy(seed: int, footprint: int, n: int) -> Trace:
    """wh (log-structured update): a circular read-modify-write sweep
    (~60% stores) over a working span ~5x the local page cache, so resident
    pages are re-dirtied line by line and every eviction is a writeback —
    the reverse CC->MC path carries roughly one dirty page per demand page
    (the uplink stress case, DESIGN.md §2.7).  The span scales with the
    trace length (floored at 16 pages) so the churn ratio — not the byte
    count — is what the workload pins across quick/full grid sizes."""
    rng = np.random.default_rng(seed)
    span = min(footprint, max(1024 * 64, n * 64))
    addrs = (np.arange(n, dtype=np.int64) * 64) % span
    gaps = rng.integers(8, 20, n)
    writes = rng.random(n) < 0.6
    return _mk(gaps, addrs, writes, footprint)


@register_workload("ph", compressibility=2.8)
def phased(seed: int, footprint: int, n: int) -> Trace:
    """ph: phase-changing — alternating streaming-scan and pointer-chase
    epochs (~500 accesses each), the regime where a fixed granularity is
    wrong half the time and adaptive selection has to track the phase."""
    rng = np.random.default_rng(seed)
    epoch = 500
    i = np.arange(n, dtype=np.int64)
    stream_phase = (i // epoch) % 2 == 0
    # streaming half: a sequential scan that keeps its cursor across epochs
    seq = (np.cumsum(stream_phase.astype(np.int64)) * 64) % (footprint // 2)
    # chase half: 3-line cavity walks in the upper half of the footprint
    run = 3
    starts = rng.integers(footprint // 2, footprint, n // run + 1) & ~63
    offs = (np.arange(run) * 64)[None, :]
    chase = (starts[:, None] + offs).reshape(-1)[:n]
    addrs = np.where(stream_phase, seq, chase)
    gaps = np.where(stream_phase, rng.integers(8, 20, n),
                    rng.integers(15, 40, n))
    writes = rng.random(n) < np.where(stream_phase, 0.1, 0.2)
    return _mk(gaps, addrs, writes, footprint)
