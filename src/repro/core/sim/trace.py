"""Synthetic workload trace generators modeled on the paper's evaluation
domains (graph processing, HPC, data analytics, bioinformatics, ML) —
DESIGN.md §2.4.

A trace is three parallel numpy arrays:
    gaps:  int32 compute cycles between consecutive memory accesses
    addrs: int64 byte addresses
    writes: bool

All generators are deterministic (seeded) and parameterized by footprint so
the local-memory fraction is meaningful.  Locality spans the spectrum the
paper stresses: pointer-chase (dr/pf-like, no locality) .. streaming (page
locality ~64 lines/page).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

Trace = Tuple[np.ndarray, np.ndarray, np.ndarray]

DEFAULT_FOOTPRINT = 32 << 20  # 32 MiB
DEFAULT_ACCESSES = 120_000

# Per-workload page compressibility (ratio ~ N(mean, 0.15*mean), >= 1):
# graphs/int data compress well; float/ML data less [paper §3(III)].
COMPRESSIBILITY = {
    "pr": 3.0, "bf": 3.0, "ts": 2.0, "nw": 2.5,
    "dr": 1.8, "pf": 2.2, "st": 4.0, "ml": 1.5,
}


def _mk(gaps, addrs, writes, footprint) -> Trace:
    return (
        np.asarray(gaps, np.int64),
        np.asarray(addrs, np.int64) % footprint,
        np.asarray(writes, bool),
    )


def ptr_chase(seed: int, footprint: int, n: int) -> Trace:
    """dr (delaunay-refinement-like): random cavity walks — jump to a random
    element record, touch 3 consecutive lines, hop.  Low page locality with
    small bursts (capacity-intensive irregular, the paper's dominant class)."""
    rng = np.random.default_rng(seed)
    run = 3  # lines per visited record
    n_runs = n // run + 1
    starts = rng.integers(0, footprint, n_runs) & ~63
    offs = (np.arange(run) * 64)[None, :]
    addrs = (starts[:, None] + offs).reshape(-1)[:n]
    writes = rng.random(n) < 0.2
    gaps = rng.integers(15, 40, n)
    return _mk(gaps, addrs, writes, footprint)


def pagerank(seed: int, footprint: int, n: int) -> Trace:
    """pr: irregular graph access —near-uniform random edge/vertex loads with a
    thin sequential rank stream.  LOW page locality: the paper's line-friendly
    class (moving 4 KiB to use 64 B)."""
    rng = np.random.default_rng(seed)
    rand = rng.integers(0, footprint * 7 // 8, n) & ~63
    seq = (np.arange(n) * 64) % (footprint // 8) + footprint * 7 // 8
    addrs = np.where(rng.random(n) < 0.85, rand, seq)
    writes = rng.random(n) < 0.15
    gaps = rng.integers(15, 40, n)
    return _mk(gaps, addrs, writes, footprint)


def bfs(seed: int, footprint: int, n: int) -> Trace:
    """bf: frontier bursts — short sequential runs at random page locations."""
    rng = np.random.default_rng(seed)
    run = 8
    n_runs = n // run
    starts = rng.integers(0, footprint, n_runs) & ~63
    offs = (np.arange(run) * 64)[None, :]
    addrs = (starts[:, None] + offs).reshape(-1)[:n]
    gaps = rng.integers(10, 30, n)
    return _mk(gaps, addrs, np.zeros(n, bool), footprint)


def streaming(seed: int, footprint: int, n: int) -> Trace:
    """st (data-analytics scan): fully sequential — maximal page locality."""
    rng = np.random.default_rng(seed)
    addrs = (np.arange(n) * 64) % footprint
    gaps = rng.integers(8, 20, n)
    writes = rng.random(n) < 0.1
    return _mk(gaps, addrs, writes, footprint)


def nw(seed: int, footprint: int, n: int) -> Trace:
    """nw (bioinformatics DP): anti-diagonal wavefront — consecutive cells
    stride by ~a row, touching ONE line per page before moving on.  The
    paper's other line-friendly workload."""
    rng = np.random.default_rng(seed)
    row_bytes = 1 << 14  # 16 KiB rows: stride skips 4 pages per step
    i = np.arange(n, dtype=np.int64)
    addrs = (i * (row_bytes + 64)) % footprint
    writes = rng.random(n) < 0.3
    gaps = rng.integers(12, 30, n)
    return _mk(gaps, addrs, writes, footprint)


def hash_join(seed: int, footprint: int, n: int) -> Trace:
    """ts (analytics): sequential probe stream + random hash-table lookups."""
    rng = np.random.default_rng(seed)
    seq = (np.arange(n) * 64) % (footprint // 2)
    ht = rng.integers(footprint // 2, footprint, n) & ~63
    addrs = np.where(np.arange(n) % 2 == 0, seq, ht)
    gaps = rng.integers(10, 25, n)
    return _mk(gaps, addrs, np.zeros(n, bool), footprint)


def kmeans(seed: int, footprint: int, n: int) -> Trace:
    """ml (embedding/recsys): random embedding-row gathers (2 lines each)
    plus a thin sequential activation stream — sparse, capacity-intensive."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, footprint * 7 // 8 >> 7, n) << 7  # 128B rows
    row_burst = rows + (np.arange(n) % 2) * 64
    seq = (np.arange(n) * 64) % (footprint // 8) + footprint * 7 // 8
    addrs = np.where(rng.random(n) < 0.85, row_burst, seq)
    gaps = rng.integers(15, 35, n)
    return _mk(gaps, addrs, np.zeros(n, bool), footprint)


def pf(seed: int, footprint: int, n: int) -> Trace:
    """pf (particle filter): sequential weight scan (page-friendly phase)
    interleaved with random ancestor gathers (resampling) — mixed locality."""
    rng = np.random.default_rng(seed)
    i = np.arange(n)
    seq = ((i // 128) * 4096 + (i % 128) * 32) % (footprint // 2)
    rnd = (rng.integers(footprint // 2, footprint, n) & ~63)
    addrs = np.where(rng.random(n) < 0.65, seq, rnd)
    gaps = rng.integers(8, 18, n)
    writes = rng.random(n) < 0.2
    return _mk(gaps, addrs, writes, footprint)


WORKLOADS: Dict[str, Callable[[int, int, int], Trace]] = {
    "pr": pagerank,
    "bf": bfs,
    "ts": hash_join,
    "nw": nw,
    "dr": ptr_chase,
    "pf": pf,
    "st": streaming,
    "ml": kmeans,
}


def generate(name: str, *, seed: int = 0, footprint: int = DEFAULT_FOOTPRINT,
             n: int = DEFAULT_ACCESSES) -> Trace:
    return WORKLOADS[name](seed, footprint, n)
