from repro.core.sim.config import SCHEMES, Metrics, SimConfig
from repro.core.sim.engine import LinkSchedule, Simulator, simulate
from repro.core.sim.runner import (
    fig2,
    fig2_spec,
    fig2_sweep,
    fig4_bottom,
    fig4_bottom_spec,
    fig4_top,
    fig4_top_spec,
    fig5_scalability,
    fig5_scalability_spec,
    geomean,
    paper_claims,
    run_one,
    slowdowns,
)
from repro.core.sim.sweep import (
    CellResult,
    Sweep,
    SweepResult,
    cell_seed,
    default_workers,
    run_sweep,
    scheme_geomean,
    scheme_ratio,
    write_bench,
)
from repro.core.sim.trace import WORKLOADS, generate

__all__ = [
    "SCHEMES", "Metrics", "SimConfig", "Simulator", "simulate", "LinkSchedule",
    "fig2", "fig2_spec", "fig2_sweep", "fig4_bottom", "fig4_bottom_spec",
    "fig4_top", "fig4_top_spec", "fig5_scalability", "fig5_scalability_spec",
    "geomean", "paper_claims",
    "run_one", "slowdowns", "WORKLOADS", "generate",
    "CellResult", "Sweep", "SweepResult", "cell_seed", "default_workers",
    "run_sweep", "scheme_geomean", "scheme_ratio", "write_bench",
]
