from repro.core.sim.config import SCHEMES, Metrics, SimConfig
from repro.core.sim.engine import LinkSchedule, Simulator, simulate
from repro.core.sim.policy import (
    MovementPolicy,
    available_policies,
    get_policy,
    register_policy,
    unregister_policy,
)
from repro.core.sim.runner import (
    ABLATION_POLICIES,
    fig2,
    fig2_spec,
    fig2_sweep,
    fig4_bottom,
    fig4_bottom_spec,
    fig4_top,
    fig4_top_spec,
    fig5_scalability,
    fig5_scalability_spec,
    fig6_ablation,
    fig6_ablation_spec,
    fig6_geomeans,
    geomean,
    paper_claims,
    run_one,
    slowdowns,
)
from repro.core.sim.sweep import (
    CellResult,
    Sweep,
    SweepResult,
    cell_seed,
    default_workers,
    run_sweep,
    scheme_geomean,
    scheme_ratio,
    write_bench,
)
from repro.core.sim.trace import (
    DEFAULT_SUITE,
    WORKLOADS,
    WorkloadSpec,
    available_workloads,
    generate,
    get_workload,
    register_trace_file,
    register_workload,
    save_trace,
    unregister_workload,
)

__all__ = [
    "SCHEMES", "Metrics", "SimConfig", "Simulator", "simulate", "LinkSchedule",
    "MovementPolicy", "available_policies", "get_policy", "register_policy",
    "unregister_policy",
    "ABLATION_POLICIES",
    "fig2", "fig2_spec", "fig2_sweep", "fig4_bottom", "fig4_bottom_spec",
    "fig4_top", "fig4_top_spec", "fig5_scalability", "fig5_scalability_spec",
    "fig6_ablation", "fig6_ablation_spec", "fig6_geomeans",
    "geomean", "paper_claims",
    "run_one", "slowdowns",
    "DEFAULT_SUITE", "WORKLOADS", "WorkloadSpec", "available_workloads",
    "generate", "get_workload", "register_trace_file", "register_workload",
    "save_trace", "unregister_workload",
    "CellResult", "Sweep", "SweepResult", "cell_seed", "default_workers",
    "run_sweep", "scheme_geomean", "scheme_ratio", "write_bench",
]
