from repro.core.sim.config import SCHEMES, Metrics, SimConfig
from repro.core.sim.engine import LinkSchedule, Simulator, simulate
from repro.core.sim.engine_batch import (
    BatchCell,
    BatchResult,
    BatchState,
    covers,
    run_batch,
)
from repro.core.sim.policy import (
    MovementPolicy,
    available_policies,
    get_policy,
    register_policy,
    unregister_policy,
)
from repro.core.sim.runner import (
    ABLATION_POLICIES,
    SERVING_LOADS,
    SERVING_ROUTERS,
    SERVING_TENANTS,
    fig2,
    fig2_spec,
    fig2_sweep,
    fig4_bottom,
    fig4_bottom_spec,
    fig4_top,
    fig4_top_spec,
    fig5_scalability,
    fig5_scalability_spec,
    fig6_ablation,
    fig6_ablation_spec,
    fig6_geomeans,
    fig7_uplink,
    fig7_uplink_spec,
    fig8_kernels,
    fig8_kernels_spec,
    fig9_serving,
    fig9_serving_spec,
    fig9_tails,
    geomean,
    paper_claims,
    run_one,
    slowdowns,
)
from repro.core.sim.serving import (
    RequestRecord,
    RequestSpec,
    RouterPolicy,
    ServingScheduler,
    available_routers,
    build_requests,
    get_router,
    register_router,
    request_arrivals,
    serve_one,
    unregister_router,
)
from repro.core.sim.sweep import (
    ENGINES,
    CellResult,
    Sweep,
    SweepResult,
    cell_seed,
    default_workers,
    run_sweep,
    scheme_geomean,
    scheme_ratio,
    wall_stats,
    write_bench,
)
from repro.core.sim.trace import (
    DEFAULT_SUITE,
    WORKLOADS,
    WorkloadSpec,
    available_workloads,
    compressibility_of,
    generate,
    get_workload,
    register_trace_file,
    register_workload,
    save_trace,
    unregister_workload,
)

# captured Pallas-kernel workloads (fa_prefill, fa_decode, mamba_fwd,
# bq_quant — DESIGN.md §2.8) register at import so they work out of the
# box; trace derivation / jax imports stay lazy until first use
from repro.capture.workloads import register_captured_kernels as _reg_captured

_reg_captured()

__all__ = [
    "SCHEMES", "Metrics", "SimConfig", "Simulator", "simulate", "LinkSchedule",
    "MovementPolicy", "available_policies", "get_policy", "register_policy",
    "unregister_policy",
    "ABLATION_POLICIES",
    "fig2", "fig2_spec", "fig2_sweep", "fig4_bottom", "fig4_bottom_spec",
    "fig4_top", "fig4_top_spec", "fig5_scalability", "fig5_scalability_spec",
    "fig6_ablation", "fig6_ablation_spec", "fig6_geomeans",
    "fig7_uplink", "fig7_uplink_spec",
    "fig8_kernels", "fig8_kernels_spec",
    "fig9_serving", "fig9_serving_spec", "fig9_tails",
    "SERVING_LOADS", "SERVING_ROUTERS", "SERVING_TENANTS",
    "RequestRecord", "RequestSpec", "RouterPolicy", "ServingScheduler",
    "available_routers", "build_requests", "get_router", "register_router",
    "request_arrivals", "serve_one", "unregister_router",
    "geomean", "paper_claims",
    "run_one", "slowdowns",
    "DEFAULT_SUITE", "WORKLOADS", "WorkloadSpec", "available_workloads",
    "compressibility_of", "generate", "get_workload", "register_trace_file",
    "register_workload", "save_trace", "unregister_workload",
    "CellResult", "Sweep", "SweepResult", "cell_seed", "default_workers",
    "run_sweep", "scheme_geomean", "scheme_ratio", "write_bench",
    "BatchCell", "BatchResult", "BatchState", "covers", "run_batch",
    "ENGINES", "wall_stats",
]
