from repro.core.sim.config import SCHEMES, Metrics, SimConfig
from repro.core.sim.engine import Simulator, simulate
from repro.core.sim.runner import (
    fig2,
    fig4_bottom,
    fig4_top,
    geomean,
    paper_claims,
    run_one,
    slowdowns,
)
from repro.core.sim.trace import WORKLOADS, generate

__all__ = [
    "SCHEMES", "Metrics", "SimConfig", "Simulator", "simulate",
    "fig2", "fig4_bottom", "fig4_top", "geomean", "paper_claims",
    "run_one", "slowdowns", "WORKLOADS", "generate",
]
