"""Simulator configuration for the disaggregated-system model (§2.1 of
DESIGN.md).  Units: CPU cycles (3 GHz nominal).  Defaults follow the paper's
evaluation: local memory fits ~20% of the application footprint, network
bandwidth is 1/2..1/8 of the memory bus bandwidth [Gao et al., OSDI'16], and
page movements may be link-compressed.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

from repro.core.sim.controller import available_controllers
from repro.core.sim.fabric import available_topologies
from repro.core.sim.memside import available_placements

# The paper's six schemes, in figure order.  Since the policy registry
# (policy.py) these are just the six legacy *registered compositions*;
# `available_policies()` lists every registered policy including ablations.
SCHEMES = ("local", "page", "page_free", "cacheline", "both", "daemon")

# the legacy static placements (memside.LEGACY_PLACEMENTS): kept for
# back-compat — mc_interleave now validates against the full placement
# registry (memside.available_placements), of which these are the subset
# that keeps the engines on the infinite-memory fast path
MC_INTERLEAVES = ("page", "hash", "single")


@dataclass(frozen=True)
class SimConfig:
    # geometry
    line_bytes: int = 64
    page_bytes: int = 4096
    header_bytes: int = 16  # per network packet

    # CCs (§2.5 of DESIGN.md): n_ccs independent compute complexes, each with
    # its own cores/LLC/local page cache (and, for daemon, its own engines),
    # all contending for the SAME per-MC downlinks.  n_ccs=1 is the legacy
    # single-CC model, bit-for-bit.
    n_ccs: int = 1
    llc_bytes: int = 1 << 21  # 2 MiB LLC
    llc_assoc: int = 16
    llc_lat: int = 30
    local_mem_frac: float = 0.2  # local memory fits ~20% of footprint
    mem_lat: int = 300  # local DRAM access latency (~100 ns)
    mlp: int = 16  # outstanding-miss window before a core stalls (OoO MSHRs)
    n_cores: int = 4  # threads per application (Sniper-style multicore CC)
    gap_scale: float = 0.25  # compute-gap scale (OoO cores retire ~4 IPC)

    # network / MCs
    n_mcs: int = 1
    bus_bw: float = 32.0  # bytes/cycle (~96 GB/s @ 3 GHz)
    link_bw_frac: float = 0.25  # network bw = frac * bus bw (1/2 .. 1/8)
    net_lat: int = 3000  # one-way propagation+protocol (~1 us)
    remote_mem_lat: int = 300  # DRAM access at the MC
    # page -> MC placement (§2.3 / §2.13 of DESIGN.md): any registered
    # placement policy (memside.available_placements).  The legacy static
    # trio ("page" / "hash" / "single") with mc_capacity_pages=None keeps
    # the infinite-memory fast path, bit-identical to every committed
    # golden; "first_touch" / "capacity_aware" (or finite capacity) turn
    # on the memory-side state subsystem.
    mc_interleave: str = "page"
    # finite per-MC capacity (§2.13): page slots per memory module, backed
    # by a slab/first-fit allocator with cross-MC spill (charged as extra
    # fabric hops) and coldest-resident eviction when the pool fills.
    # ``None`` (default) is the legacy infinite passive address space —
    # bit-identical to every committed golden.
    mc_capacity_pages: Optional[int] = None
    # hot-page dynamics (§2.13, finite capacity only): line fetches to a
    # still-remote resident before the engines promote it toward the
    # owning CC's page cache (throttled by the controller's backlog
    # signal; eviction writebacks ride the §2.7 uplink)
    mem_hot_threshold: int = 8

    # CC->MC uplink (§2.7 of DESIGN.md).  ``None`` (default) is the legacy
    # model: the request path is folded into ``net_lat`` and dirty-page
    # writebacks are injected into the *downlink* queue — bit-identical to
    # every committed golden.  A float (bytes/cycle) makes the reverse path
    # a first-class contended resource: line/page request packets
    # (``header_bytes`` each) and writebacks queue on a per-MC uplink whose
    # arbitration follows the policy's ``uplink`` component.  Disaggregated
    # fabrics are commonly asymmetric (uplink_bw < link_bw).
    uplink_bw: Optional[float] = None
    # dual-queue uplinks: bandwidth fraction of the writeback (bulk) class
    # when both classes are backlogged; request packets keep the rest
    # (mirrors line_share on the downlink).
    writeback_share: float = 0.4

    # routed network fabric (§2.11 of DESIGN.md).  ``None`` (default) is the
    # legacy flat model: one private link per MC and direction, bit-identical
    # to every committed golden.  A registered topology name (fabric.py:
    # direct / single_switch / two_tier) routes every CC<->MC transfer over
    # an explicit multi-hop path of directed ports with store-and-forward
    # switching (``switch_lat`` cycles of processing per switch hop) and
    # per-port fluid arbitration.  ``oversub`` provisions the two_tier spine
    # trunks at aggregate_endpoint_bw/oversub (>= 1.0; inert for direct and
    # single_switch, and accepted there so sweep axes stay composable).
    topology: Optional[str] = None
    oversub: float = 1.0
    switch_lat: int = 500  # store-and-forward processing per switch hop

    # scenario axis: time-varying network (§5 of DESIGN.md).  Models fabric
    # congestion: each link resamples per ``jitter_period`` cycles an
    # *available*-bandwidth multiplier 1 - bw_jitter*U[0,1) (floored at 0.05;
    # capacity is the ceiling, dips below it) and a latency multiplier
    # 1 + lat_jitter*U[0,1) (propagation is the floor, queueing adds to it).
    # Zero jitter is the exact legacy fixed-network model.
    bw_jitter: float = 0.0
    lat_jitter: float = 0.0
    jitter_period: int = 20_000  # cycles per variability epoch (~6.7 us)
    jitter_seed: int = 0

    # DaeMon
    line_share: float = 0.6  # bandwidth fraction reserved for the sub-block queue
    inflight_lines: int = 128  # inflight sub-block buffer capacity
    inflight_pages: int = 16  # inflight page buffer capacity
    page_throttle_hi: float = 0.75  # stop issuing pages above this utilization
    # movement controller (§2.12 of DESIGN.md): the registered
    # MovementController driving the selection/throttle/compression
    # decisions on every CC.  ``None`` resolves to the legacy ``fixed``
    # constants — bit-identical to every committed golden.  A policy's
    # explicit ``controller`` component overrides this per CC.
    controller: Optional[str] = None
    compress: bool = True
    comp_lat: int = 750  # page compression latency at the MC (~250 ns)
    decomp_lat: int = 750  # page decompression latency at the CC

    # request-level serving layer (§2.9 of DESIGN.md).  ``serving_router``
    # is ``None`` by default — the legacy closed-loop model, no request
    # layer, bit-identical to every committed golden.  A registered
    # RouterPolicy name (serving.py: round_robin / least_loaded /
    # disagg_prefill) turns the cell into an open-loop LLM-serving
    # simulation: Poisson arrivals at ``offered_load`` requests per Mcycle,
    # each request one ``prefill_workload`` burst of ``prefill_accesses``
    # followed by ``decode_steps`` x ``decode_accesses`` slices of
    # ``decode_workload``, scheduled onto per-CC request slots (n_cores).
    serving_router: Optional[str] = None
    offered_load: float = 4.0  # requests per 1e6 cycles (open loop)
    n_requests: int = 32
    prefill_workload: str = "fa_prefill"
    decode_workload: str = "fa_decode"
    prefill_accesses: int = 1024
    decode_steps: int = 4
    decode_accesses: int = 256
    # fraction of CCs in the prefill pool for disaggregated routers
    serving_prefill_frac: float = 0.5
    # per-pool MovementPolicy overrides (registered policy names) for
    # disaggregated routers; None = the cell's scheme on every CC
    serving_prefill_policy: Optional[str] = None
    serving_decode_policy: Optional[str] = None
    # per-pool MovementController overrides (registered controller names)
    # for disaggregated routers, mirroring the per-pool policy overrides;
    # None = the cell's controller resolution on every CC
    serving_prefill_controller: Optional[str] = None
    serving_decode_controller: Optional[str] = None
    # stop firing events past this cycle horizon (None = drain all requests)
    serving_horizon: Optional[float] = None

    def __post_init__(self):
        """Fail-fast validation at config construction time (DESIGN.md §2.1)
        — a bad parameter should never survive until deep inside a sweep."""
        # placements (§2.13) — names resolve against the registry at
        # construction time, like policies/workloads/topologies
        if self.mc_interleave not in available_placements():
            raise ValueError(
                f"mc_interleave={self.mc_interleave!r} not registered; "
                f"choose from {available_placements()}")
        if self.mc_capacity_pages is not None and self.mc_capacity_pages < 1:
            raise ValueError(
                f"mc_capacity_pages={self.mc_capacity_pages} must be >= 1 "
                f"(or None for the legacy infinite model)")
        if self.mem_hot_threshold < 1:
            raise ValueError(
                f"mem_hot_threshold={self.mem_hot_threshold} must be >= 1")
        for name, lo in (("n_ccs", 1), ("n_mcs", 1), ("n_cores", 1),
                         ("line_bytes", 1), ("page_bytes", 1), ("mlp", 1)):
            if getattr(self, name) < lo:
                raise ValueError(f"{name}={getattr(self, name)} must be >= {lo}")
        if self.page_bytes % self.line_bytes:
            raise ValueError(
                f"page_bytes={self.page_bytes} must be a multiple of "
                f"line_bytes={self.line_bytes}")
        for name in ("bus_bw", "link_bw_frac", "local_mem_frac", "gap_scale"):
            if getattr(self, name) <= 0:
                raise ValueError(f"{name}={getattr(self, name)} must be > 0")
        if not (0.0 < self.line_share < 1.0):
            raise ValueError(f"line_share={self.line_share} must be in (0, 1)")
        if self.uplink_bw is not None and self.uplink_bw <= 0:
            raise ValueError(
                f"uplink_bw={self.uplink_bw} must be > 0 (or None for the "
                f"legacy folded-into-net_lat model)")
        if not (0.0 < self.writeback_share < 1.0):
            raise ValueError(
                f"writeback_share={self.writeback_share} must be in (0, 1)")
        # routed fabric (§2.11) — topology names resolve against the
        # registry at construction time, like policies and workloads
        if self.topology is not None and \
                self.topology not in available_topologies():
            raise ValueError(
                f"topology={self.topology!r} not registered; choose from "
                f"{available_topologies()} (or None for the legacy flat "
                f"per-MC links)")
        if self.oversub < 1.0:
            raise ValueError(
                f"oversub={self.oversub} must be >= 1.0 "
                f"(1.0 = non-blocking trunks)")
        if self.switch_lat < 0:
            raise ValueError(
                f"switch_lat={self.switch_lat} must be >= 0")
        # movement controllers (§2.12) — names resolve against the registry
        # at construction time, like policies/workloads/topologies
        for name in ("controller", "serving_prefill_controller",
                     "serving_decode_controller"):
            v = getattr(self, name)
            if v is not None and v not in available_controllers():
                raise ValueError(
                    f"{name}={v!r} not registered; choose from "
                    f"{available_controllers()} (or None for the legacy "
                    f"fixed constants)")
        for name in ("bw_jitter", "lat_jitter"):
            if not (0.0 <= getattr(self, name) <= 1.0):
                raise ValueError(
                    f"{name}={getattr(self, name)} must be in [0, 1]")
        # serving layer (§2.9) — validated whether or not a router is set,
        # so a bad sweep axis value fails at config construction time
        for name in ("n_requests", "prefill_accesses", "decode_accesses"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name}={getattr(self, name)} must be >= 1")
        if self.decode_steps < 0:
            raise ValueError(f"decode_steps={self.decode_steps} must be >= 0")
        if self.offered_load <= 0:
            raise ValueError(
                f"offered_load={self.offered_load} must be > 0 "
                f"(requests per Mcycle)")
        if not (0.0 < self.serving_prefill_frac < 1.0):
            raise ValueError(
                f"serving_prefill_frac={self.serving_prefill_frac} "
                f"must be in (0, 1)")
        if self.serving_horizon is not None and self.serving_horizon <= 0:
            raise ValueError(
                f"serving_horizon={self.serving_horizon} must be > 0 "
                f"(or None to drain all requests)")

    @property
    def link_bw(self) -> float:
        return self.bus_bw * self.link_bw_frac

    def with_(self, **kw) -> "SimConfig":
        return replace(self, **kw)


@dataclass
class Metrics:
    scheme: str = ""
    workload: str = ""
    cycles: float = 0.0  # end-to-end execution time
    accesses: int = 0
    llc_hits: int = 0
    local_hits: int = 0
    remote_misses: int = 0
    miss_latency_sum: float = 0.0  # total cycles spent servicing LLC misses
    net_bytes: float = 0.0  # bytes transmitted MC->CC (downlink; with the
    # legacy uplink_bw=None model this also includes writeback bytes)
    uplink_bytes: float = 0.0  # bytes transmitted CC->MC (request packets +
    # writebacks); always 0 under the legacy uplink_bw=None model
    pages_moved: int = 0
    lines_moved: int = 0
    writebacks: int = 0  # dirty-page evictions written back to the MC
    bytes_saved_compression: float = 0.0
    # count of stall *episodes* (each time a core's mlp window fills), NOT
    # stalled cycles — see DESIGN.md §2.2
    stall_episodes: float = 0.0
    # memory-side state counters (§2.13): cell-global (the pool is shared
    # across CCs, so these are not attributed per CC — per_cc entries
    # carry zeros); all-zero under the legacy infinite model.
    mc_spills: int = 0      # allocations that landed off their home MC
    mc_evictions: int = 0   # cold residents dropped from a full pool
    mc_promotions: int = 0  # hot-page migrations issued toward a CC
    # multi-CC rollup (§2.5): one entry per CC (cc index, per-CC workload,
    # and the full per-CC counter set); empty for single-CC runs, where the
    # aggregate IS the (only) CC's metrics.
    per_cc: list = field(default_factory=list)
    # request-level serving rollup (§2.9): populated only by serve_one
    # (cfg.serving_router set); all-zero/empty for legacy closed-loop runs.
    requests_offered: int = 0
    requests_completed: int = 0
    request_p50: float = 0.0  # median request latency (cycles)
    request_p99: float = 0.0  # tail request latency (cycles)
    goodput: float = 0.0  # completed requests per Mcycle of makespan
    requests: list = field(default_factory=list)  # per-request records

    @property
    def avg_access_cost(self) -> float:
        """Average LLC-miss service latency — the paper's 'data access cost'."""
        n = self.llc_misses
        return self.miss_latency_sum / n if n else 0.0

    @property
    def llc_misses(self) -> int:
        return self.local_hits + self.remote_misses

    def as_dict(self) -> dict:
        return {
            "scheme": self.scheme,
            "workload": self.workload,
            "cycles": self.cycles,
            "avg_access_cost": self.avg_access_cost,
            "accesses": self.accesses,
            "net_bytes": self.net_bytes,
            "uplink_bytes": self.uplink_bytes,
            "pages_moved": self.pages_moved,
            "lines_moved": self.lines_moved,
            "writebacks": self.writebacks,
            "llc_hits": self.llc_hits,
            "local_hits": self.local_hits,
            "remote_misses": self.remote_misses,
            "miss_latency_sum": self.miss_latency_sum,
            "stall_episodes": self.stall_episodes,
            "bytes_saved_compression": self.bytes_saved_compression,
            "mc_spills": self.mc_spills,
            "mc_evictions": self.mc_evictions,
            "mc_promotions": self.mc_promotions,
            "per_cc": self.per_cc,
            "requests_offered": self.requests_offered,
            "requests_completed": self.requests_completed,
            "request_p50": self.request_p50,
            "request_p99": self.request_p99,
            "goodput": self.goodput,
            "requests": self.requests,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Metrics":
        """Inverse of :meth:`as_dict` (derived keys are ignored)."""
        fields = {k: v for k, v in d.items() if k in cls.__dataclass_fields__}
        return cls(**fields)
