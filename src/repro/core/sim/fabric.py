"""Topology-aware network fabric (DESIGN.md §2.11).

The flat link model (engine.py) gives each MC one private downlink (and,
with ``uplink_bw``, one private uplink) to the compute side — the binding
constraint is always the endpoint link.  At fleet scale the binding
constraint is fabric *oversubscription* between pooled compute and memory:
CC<->MC transfers cross shared switch trunks provisioned below the
aggregate endpoint bandwidth.  This module generalizes the fluid-link
machinery into a routed graph of directed port links, following the CCL
Simulator model (SNIPPETS.md §1):

- every CC->MC and MC->CC transfer resolves to an explicit multi-hop
  *path* of directed ports;
- forwarding is store-and-forward: a transfer fully drains one port, sits
  ``switch_lat`` cycles in the switch, then queues on the next port;
- each port is a single-server output queue with fluid arbitration across
  all flows sharing it (round-robin packet arbitration in the fluid
  limit) — the same link classes the flat model uses, so DaeMon's
  dual-queue line/page partitioning is preserved end-to-end on every hop
  while FIFO baselines get FIFO ports;
- no congestion control, no loss (as in the CCL model).

A topology is a registered builder function producing a
:class:`TopologySpec` — the port list plus the (mc, cc) -> path tables:

    @register_topology("direct", description="...")
    def _direct(*, n_ccs, n_mcs, oversub):
        ...

``direct`` reproduces today's flat per-MC links as 1-hop paths
(bit-identical to ``topology=None``); ``single_switch`` routes everything
through one non-blocking switch; ``two_tier`` adds leaf->spine trunks
provisioned at ``aggregate_endpoint_bw / oversub`` — the oversubscription
regime the sweep in benchmarks/fig10_topology.py measures.

This module is deliberately free of imports from the rest of the package
(config.py imports it for validation): the :class:`Fabric` runtime takes
an injected event engine and per-port link factories, so the engine — not
this module — decides which arbitration class backs each port.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

__all__ = [
    "PortSpec",
    "TopologySpec",
    "Fabric",
    "FabricRoute",
    "register_topology",
    "unregister_topology",
    "get_topology",
    "available_topologies",
    "topology_description",
    "build_topology",
]


# --------------------------------------------------------------------------
# specs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class PortSpec:
    """One directed port link of a topology.

    ``bw_frac`` scales the direction's endpoint bandwidth (``link_bw`` for
    down ports, ``uplink_bw`` for up ports) — a trunk aggregating k
    endpoint links at oversubscription O declares ``bw_frac = k / O``.
    ``mc`` attaches that MC's :class:`~repro.core.sim.engine.LinkSchedule`
    (network weather stays per-MC-link, as in the flat model; switch-
    internal trunks are weather-free).  ``switch`` marks switch-owned
    ports, whose arbitration follows the policy's ``fabric`` component
    instead of the endpoint ``partitioning``/``uplink`` components."""

    name: str
    down: bool  # MC->CC direction (False: CC->MC)
    bw_frac: float = 1.0
    mc: Optional[int] = None
    switch: bool = False


@dataclass(frozen=True)
class TopologySpec:
    """A built topology: the ports plus the per-(endpoint pair) paths.

    ``down_paths[(mc, cc)]`` / ``up_paths[(cc, mc)]`` are tuples of port
    names crossed in order; within one topology every path of a direction
    has the same hop count."""

    name: str
    n_ccs: int
    n_mcs: int
    oversub: float
    ports: Tuple[PortSpec, ...]
    down_paths: Dict[Tuple[int, int], Tuple[str, ...]]
    up_paths: Dict[Tuple[int, int], Tuple[str, ...]]

    def validate(self) -> "TopologySpec":
        names = [p.name for p in self.ports]
        if len(set(names)) != len(names):
            raise ValueError(f"topology {self.name!r}: duplicate port names")
        by_name = {p.name: p for p in self.ports}
        for (table, down) in ((self.down_paths, True), (self.up_paths, False)):
            pairs = {(a, b) for a in range(self.n_mcs if down else self.n_ccs)
                     for b in range(self.n_ccs if down else self.n_mcs)}
            if set(table) != pairs:
                raise ValueError(
                    f"topology {self.name!r}: "
                    f"{'down' if down else 'up'}_paths must cover exactly "
                    f"every (mc, cc) pair")
            for path in table.values():
                if not path:
                    raise ValueError(f"topology {self.name!r}: empty path")
                for pn in path:
                    p = by_name.get(pn)
                    if p is None:
                        raise ValueError(
                            f"topology {self.name!r}: path references "
                            f"undeclared port {pn!r}")
                    if p.down != down:
                        raise ValueError(
                            f"topology {self.name!r}: port {pn!r} used "
                            f"against its direction")
        return self


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

# name -> (builder(*, n_ccs, n_mcs, oversub) -> TopologySpec, description)
_TOPOLOGIES: Dict[str, Tuple[Callable[..., TopologySpec], str]] = {}


def register_topology(name: str, *, description: str = "",
                      overwrite: bool = False):
    """Decorator: register a topology builder under ``name``.  The builder
    takes keyword-only ``n_ccs``, ``n_mcs``, ``oversub`` and returns a
    :class:`TopologySpec`."""
    if not name or "+" in name or "/" in name:
        raise ValueError(f"bad topology name {name!r}")

    def deco(fn: Callable[..., TopologySpec]):
        if name in _TOPOLOGIES and not overwrite:
            raise ValueError(
                f"topology {name!r} already registered "
                f"(pass overwrite=True to replace)")
        _TOPOLOGIES[name] = (fn, description)
        return fn

    return deco


def unregister_topology(name: str) -> None:
    """Remove a registered topology (tests / experimentation)."""
    _TOPOLOGIES.pop(name, None)


def get_topology(name: str) -> Callable[..., TopologySpec]:
    """Resolve a topology builder; unknown names fail fast listing choices."""
    entry = _TOPOLOGIES.get(name)
    if entry is None:
        raise KeyError(
            f"unknown topology {name!r}; registered topologies: "
            f"{', '.join(available_topologies())}")
    return entry[0]


def available_topologies() -> Tuple[str, ...]:
    return tuple(_TOPOLOGIES)


def topology_description(name: str) -> str:
    entry = _TOPOLOGIES.get(name)
    if entry is None:
        raise KeyError(
            f"unknown topology {name!r}; registered topologies: "
            f"{', '.join(available_topologies())}")
    return entry[1]


def build_topology(name: str, *, n_ccs: int, n_mcs: int,
                   oversub: float = 1.0) -> TopologySpec:
    """Build and validate the named topology for a system shape."""
    if n_ccs < 1 or n_mcs < 1:
        raise ValueError(f"n_ccs={n_ccs} / n_mcs={n_mcs} must be >= 1")
    if oversub < 1.0:
        raise ValueError(f"oversub={oversub} must be >= 1.0")
    return get_topology(name)(n_ccs=n_ccs, n_mcs=n_mcs,
                              oversub=oversub).validate()


# --------------------------------------------------------------------------
# built-in topologies
# --------------------------------------------------------------------------


@register_topology("direct", description=(
        "flat per-MC point-to-point links (the legacy model as 1-hop "
        "paths; oversub is inert)"))
def _direct(*, n_ccs: int, n_mcs: int, oversub: float) -> TopologySpec:
    ports = []
    down_paths, up_paths = {}, {}
    for j in range(n_mcs):
        ports.append(PortSpec(f"d:mc{j}", down=True, mc=j))
        ports.append(PortSpec(f"u:mc{j}", down=False, mc=j))
        for i in range(n_ccs):
            down_paths[(j, i)] = (f"d:mc{j}",)
            up_paths[(i, j)] = (f"u:mc{j}",)
    return TopologySpec("direct", n_ccs, n_mcs, oversub, tuple(ports),
                        down_paths, up_paths)


@register_topology("single_switch", description=(
        "one non-blocking switch between all CCs and MCs: per-CC egress "
        "ports aggregate cross-MC traffic (oversub is inert)"))
def _single_switch(*, n_ccs: int, n_mcs: int, oversub: float) -> TopologySpec:
    ports = []
    down_paths, up_paths = {}, {}
    for j in range(n_mcs):
        ports.append(PortSpec(f"d:mc{j}", down=True, mc=j))
        ports.append(PortSpec(f"u:sw>mc{j}", down=False, mc=j, switch=True))
    for i in range(n_ccs):
        ports.append(PortSpec(f"d:sw>cc{i}", down=True, switch=True))
        ports.append(PortSpec(f"u:cc{i}", down=False))
    for j in range(n_mcs):
        for i in range(n_ccs):
            down_paths[(j, i)] = (f"d:mc{j}", f"d:sw>cc{i}")
            up_paths[(i, j)] = (f"u:cc{i}", f"u:sw>mc{j}")
    return TopologySpec("single_switch", n_ccs, n_mcs, oversub, tuple(ports),
                        down_paths, up_paths)


@register_topology("two_tier", description=(
        "leaf/spine: endpoint NICs feed leaf switches whose spine trunks "
        "carry aggregate_endpoint_bw/oversub — the oversubscribed tier"))
def _two_tier(*, n_ccs: int, n_mcs: int, oversub: float) -> TopologySpec:
    """MCs hang off a memory-side leaf, CCs off a compute-side leaf; the
    two leaves exchange traffic through spine trunks provisioned at the
    aggregate endpoint bandwidth of their source tier divided by
    ``oversub`` (oversub=1.0 is non-blocking)."""
    ports = [
        PortSpec("d:leafm>spine", down=True, bw_frac=n_mcs / oversub,
                 switch=True),
        PortSpec("d:spine>leafc", down=True, bw_frac=n_ccs / oversub,
                 switch=True),
        PortSpec("u:leafc>spine", down=False, bw_frac=n_ccs / oversub,
                 switch=True),
        PortSpec("u:spine>leafm", down=False, bw_frac=n_mcs / oversub,
                 switch=True),
    ]
    down_paths, up_paths = {}, {}
    for j in range(n_mcs):
        ports.append(PortSpec(f"d:mc{j}", down=True, mc=j))
        ports.append(PortSpec(f"u:leafm>mc{j}", down=False, mc=j,
                              switch=True))
    for i in range(n_ccs):
        ports.append(PortSpec(f"d:leafc>cc{i}", down=True, switch=True))
        ports.append(PortSpec(f"u:cc{i}", down=False))
    for j in range(n_mcs):
        for i in range(n_ccs):
            down_paths[(j, i)] = (f"d:mc{j}", "d:leafm>spine",
                                  "d:spine>leafc", f"d:leafc>cc{i}")
            up_paths[(i, j)] = (f"u:cc{i}", "u:leafc>spine",
                                "u:spine>leafm", f"u:leafm>mc{j}")
    return TopologySpec("two_tier", n_ccs, n_mcs, oversub, tuple(ports),
                        down_paths, up_paths)


# --------------------------------------------------------------------------
# runtime
# --------------------------------------------------------------------------


class FabricRoute:
    """Legacy-link facade over one direction of the fabric for one MC: the
    engine keeps calling ``links[mc].send(t, size, cb, cls, flow)`` /
    ``uplinks[mc].backlog(t)`` and this facade resolves the flow's path,
    forwards the transfer hop by hop (store-and-forward: each port fully
    drains the transfer, then ``switch_lat`` cycles of switch processing,
    then the next port), and fires ``cb`` when the LAST hop's transmission
    completes — the caller adds the end-to-end propagation ``net_lat``
    afterwards, exactly as with a flat link.  On 1-hop paths (``direct``)
    the event sequence is identical to the flat link's, bit for bit."""

    def __init__(self, fabric: "Fabric", direction: str,
                 paths: Dict[int, Tuple[str, ...]]):
        self.fabric = fabric
        self.direction = direction
        self.paths = paths
        seen: Dict[str, None] = {}
        for path in paths.values():
            for pn in path:
                seen.setdefault(pn)
        self.port_names: Tuple[str, ...] = tuple(seen)

    def send(self, t: float, size: float, cb: Callable[[float], None],
             cls: str = "line", flow: int = 0):
        fab = self.fabric
        path = self.paths[flow]
        last = len(path) - 1
        fab.sent[self.direction] += size

        def final(a: float):
            fab.delivered[self.direction] += size
            cb(a)

        def hop(i: int, tt: float):
            port = fab.ports[path[i]]
            if i == last:
                port.send(tt, size, final, cls, flow)
            else:
                port.send(
                    tt, size,
                    lambda a, _i=i: fab.eng.at(
                        a + fab.switch_lat, lambda b, _j=_i: hop(_j + 1, b)),
                    cls, flow)

        hop(0, t)

    def backlog(self, t: float) -> float:
        """Outstanding bytes across every port this route crosses (the
        congestion signal writeback compression keys off, DESIGN.md §2.7
        — aggregated over the hops rather than one flat queue)."""
        ports = self.fabric.ports
        return sum(ports[pn].backlog(t) for pn in self.port_names)


class Fabric:
    """Instantiated topology: one link object per port (built by the
    injected ``port_link`` factory, so the engine picks the arbitration
    class per port) plus per-direction byte-conservation counters —
    ``sent[d] == delivered[d]`` once the event heap drains, however many
    hops each transfer crossed."""

    def __init__(self, eng, spec: TopologySpec, switch_lat: float,
                 port_link: Callable[[PortSpec], object], *,
                 include_up: bool = True):
        self.eng = eng
        self.spec = spec
        self.switch_lat = float(switch_lat)
        self.ports: Dict[str, object] = {}
        for p in spec.ports:
            if not p.down and not include_up:
                continue  # folded request path: no up ports exist
            self.ports[p.name] = port_link(p)
        self.sent = {"down": 0.0, "up": 0.0}
        self.delivered = {"down": 0.0, "up": 0.0}

    def down_route(self, mc: int) -> FabricRoute:
        return FabricRoute(self, "down", {
            cc: self.spec.down_paths[(mc, cc)]
            for cc in range(self.spec.n_ccs)})

    def up_route(self, mc: int) -> FabricRoute:
        return FabricRoute(self, "up", {
            cc: self.spec.up_paths[(cc, mc)]
            for cc in range(self.spec.n_ccs)})

    def up_hops(self, mc: int) -> int:
        """Switch hops on the CC->MC request path (path length - 1) — the
        store-and-forward processing the *folded* request model charges as
        pure latency when no explicit uplink exists."""
        return len(self.spec.up_paths[(0, mc)]) - 1
