"""Composable data-movement policies (DESIGN.md §2.6).

The paper's core claim is that DaeMon's gains come from the *synergy* of
four orthogonal techniques — decoupled multi-granularity movement,
bandwidth partitioning, link compression, and adaptive granularity
selection.  A :class:`MovementPolicy` names one value per component, the
engine dispatches on components (never on policy names), and the
``@register_policy`` registry makes every composition a first-class,
string-addressable citizen of ``run_one`` / ``Sweep`` axes / benchmark
CLIs.

The six legacy schemes are registered compositions that reproduce the
pre-registry engine bit-for-bit (locked by tests/test_multicc.py goldens);
ablation policies (``daemon_nocomp``, ``daemon_fifo``, ``daemon_fixed_gran``,
``both_dualq``, ``page_dualq``) are just more compositions — no engine
edits.  Define your own in ~5 lines:

    from repro.core.sim import MovementPolicy, register_policy, run_one

    register_policy(MovementPolicy(
        name="daemon_lowshare", granularity="adaptive", partitioning="dual",
        compression="link", throttle=True, line_share=0.3))
    run_one("pr", "daemon_lowshare")
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, Optional, Tuple, Union

from repro.core.sim.controller import available_controllers

GRANULARITIES = ("none", "line", "page", "both", "adaptive")
PARTITIONINGS = ("fifo", "dual")
COMPRESSIONS = ("off", "link")
UPLINKS = (None, "fifo", "dual")
FABRICS = (None, "fifo", "dual")


@dataclass(frozen=True)
class MovementPolicy:
    """One data-movement policy as a composition of orthogonal components.

    granularity — what an LLC miss moves over the network:
        ``none``      nothing (monolithic local-memory upper bound);
        ``line``      the 64 B line only, no local-memory migration;
        ``page``      the 4 KiB page only (requests ride the migration);
        ``both``      line AND page for every triggering miss (fixed);
        ``adaptive``  DaeMon's selection unit: inflight-buffer utilization
                      decides when to race lines and skip redundant ones.
    partitioning — how the downlink arbitrates line vs page traffic:
        ``fifo``      one store-and-forward queue, transfers serialize;
        ``dual``      decoupled queues, the line class keeps ``line_share``
                      of the bandwidth whenever it is backlogged.
    uplink — how the CC->MC uplink (active only when ``SimConfig.uplink_bw``
        is set; DESIGN.md §2.7) arbitrates request packets vs writeback
        bulk: ``fifo`` (requests suffer head-of-line blocking behind 4 KiB
        writebacks), ``dual`` (requests keep ``1 - writeback_share`` of the
        uplink whenever backlogged), or ``None`` (default) to follow the
        ``partitioning`` component — daemon protects its request packets,
        FIFO baselines do not.
    fabric — how *switch-owned* fabric ports arbitrate when
        ``SimConfig.topology`` routes transfers through switches
        (DESIGN.md §2.11): ``fifo`` / ``dual`` force that arbitration on
        every switch hop, or ``None`` (default) to follow the direction's
        endpoint arbitration (``partitioning`` downlink, ``uplink``
        uplink) — daemon keeps its protected line class end-to-end on
        every hop, FIFO baselines stay FIFO on every hop.  Endpoint NIC
        ports always follow the endpoint components, so the ``direct``
        topology reproduces the flat model whatever this is set to.
    compression — ``off`` or ``link``: congestion-triggered page
        compression at the MC (per-workload ratios; paper §3-III).
        ``link`` still honors the global ``SimConfig.compress`` switch.
    throttle — inflight-buffer caps + retry queue (part of the paper's
        selection unit): pages stop issuing above ``page_throttle_hi``
        utilization, misses park in a retry queue when both buffers fill.
    free_transfers — pages arrive at zero network cost (the idealized
        locality bound; ``page_free``).
    page_carries_requests — whether requests attach to an inflight page
        migration and complete on its arrival.  ``False`` is the legacy
        ``both`` race semantics: the line carries the request and the page
        is pure prefetch.  Only meaningful for ``both`` granularity.
    line_share — per-policy override of ``SimConfig.line_share`` for
        ``dual`` partitioning (``None`` = use the config's value).
    controller — the registered :class:`MovementController` driving this
        policy's selection/throttle/compression decisions (DESIGN.md
        §2.12).  ``None`` (default) follows ``SimConfig.controller``,
        which itself defaults to the legacy ``fixed`` constants; an
        explicit name here wins over the config (the serving layer's
        per-pool overrides ride this precedence).
    """

    name: str
    granularity: str = "adaptive"
    partitioning: str = "dual"
    uplink: Optional[str] = None
    fabric: Optional[str] = None
    compression: str = "link"
    throttle: bool = True
    free_transfers: bool = False
    page_carries_requests: bool = True
    line_share: Optional[float] = None
    controller: Optional[str] = None
    description: str = ""

    def __post_init__(self):
        if not self.name or "+" in self.name or "/" in self.name:
            raise ValueError(f"bad policy name {self.name!r}")
        if self.granularity not in GRANULARITIES:
            raise ValueError(
                f"policy {self.name!r}: granularity={self.granularity!r} "
                f"not in {GRANULARITIES}")
        if self.partitioning not in PARTITIONINGS:
            raise ValueError(
                f"policy {self.name!r}: partitioning={self.partitioning!r} "
                f"not in {PARTITIONINGS}")
        if self.uplink not in UPLINKS:
            raise ValueError(
                f"policy {self.name!r}: uplink={self.uplink!r} "
                f"not in {UPLINKS}")
        if self.fabric not in FABRICS:
            raise ValueError(
                f"policy {self.name!r}: fabric={self.fabric!r} "
                f"not in {FABRICS}")
        if self.compression not in COMPRESSIONS:
            raise ValueError(
                f"policy {self.name!r}: compression={self.compression!r} "
                f"not in {COMPRESSIONS}")
        if not self.page_carries_requests and self.granularity != "both":
            raise ValueError(
                f"policy {self.name!r}: page_carries_requests=False is the "
                f"legacy 'both' race semantics; granularity must be 'both'")
        if self.free_transfers and self.granularity != "page":
            raise ValueError(
                f"policy {self.name!r}: free_transfers requires "
                f"granularity='page'")
        if self.line_share is not None and not (0.0 < self.line_share < 1.0):
            raise ValueError(
                f"policy {self.name!r}: line_share={self.line_share} "
                f"must be in (0, 1)")
        if self.controller is not None and \
                self.controller not in available_controllers():
            raise ValueError(
                f"policy {self.name!r}: controller={self.controller!r} "
                f"not registered; choose from {available_controllers()} "
                f"(or None to follow SimConfig.controller)")

    @property
    def moves_pages(self) -> bool:
        return self.granularity in ("page", "both", "adaptive")

    @property
    def uplink_partitioning(self) -> str:
        """The resolved uplink arbitration: explicit ``uplink``, else the
        downlink ``partitioning`` component."""
        return self.uplink if self.uplink is not None else self.partitioning

    def with_(self, **kw) -> "MovementPolicy":
        """Derive a variant (give it a new ``name`` before registering)."""
        return replace(self, **kw)

    def components(self) -> Dict[str, object]:
        """The component matrix row for docs / ``benchmarks.run --list``."""
        return {
            "granularity": self.granularity,
            "partitioning": self.partitioning,
            "uplink": self.uplink_partitioning,
            "fabric": self.fabric,
            "compression": self.compression,
            "throttle": self.throttle,
            "free_transfers": self.free_transfers,
            "page_carries_requests": self.page_carries_requests,
            "line_share": self.line_share,
            "controller": self.controller,
        }


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_POLICIES: Dict[str, MovementPolicy] = {}

PolicyLike = Union[MovementPolicy, Callable[[], MovementPolicy]]


def register_policy(obj: PolicyLike, *, overwrite: bool = False) -> PolicyLike:
    """Register a :class:`MovementPolicy` under its ``name``.

    Accepts a policy instance or (decorator form) a zero-arg factory
    returning one.  Duplicate names raise unless ``overwrite=True``.
    Returns ``obj`` unchanged so it composes as a decorator.
    """
    pol = obj() if callable(obj) and not isinstance(obj, MovementPolicy) else obj
    if not isinstance(pol, MovementPolicy):
        raise TypeError(f"register_policy needs a MovementPolicy, got {pol!r}")
    if pol.name in _POLICIES and not overwrite:
        raise ValueError(
            f"policy {pol.name!r} already registered "
            f"(pass overwrite=True to replace)")
    _POLICIES[pol.name] = pol
    return obj


def unregister_policy(name: str) -> None:
    """Remove a registered policy (tests / interactive experimentation)."""
    _POLICIES.pop(name, None)


def get_policy(name: Union[str, MovementPolicy]) -> MovementPolicy:
    """Resolve a policy by name; unknown names fail fast listing choices."""
    if isinstance(name, MovementPolicy):
        return name
    pol = _POLICIES.get(name)
    if pol is None:
        raise KeyError(
            f"unknown policy {name!r}; registered policies: "
            f"{', '.join(available_policies())}")
    return pol


def available_policies() -> Tuple[str, ...]:
    return tuple(_POLICIES)


# --------------------------------------------------------------------------
# built-in compositions
# --------------------------------------------------------------------------

# the six legacy schemes, bit-identical to the pre-registry engine
register_policy(MovementPolicy(
    name="local", granularity="none", partitioning="fifo", compression="off",
    throttle=False,
    description="monolithic upper bound: every LLC miss is a local DRAM access"))
register_policy(MovementPolicy(
    name="cacheline", granularity="line", partitioning="fifo",
    compression="off", throttle=False,
    description="move only 64 B lines into the LLC (no local-memory migration)"))
register_policy(MovementPolicy(
    name="page", granularity="page", partitioning="fifo", compression="off",
    throttle=False,
    description="migrate 4 KiB pages into local memory over a FIFO link"))
register_policy(MovementPolicy(
    name="page_free", granularity="page", partitioning="fifo",
    compression="off", throttle=False, free_transfers=True,
    description="page scheme with zero-cost transfers (idealized locality bound)"))
register_policy(MovementPolicy(
    name="both", granularity="both", partitioning="fifo", compression="off",
    throttle=False, page_carries_requests=False,
    description="naive line+page race on the SAME FIFO link; the line "
                "carries the request, the page is pure prefetch"))
register_policy(MovementPolicy(
    name="daemon", granularity="adaptive", partitioning="dual",
    compression="link", throttle=True,
    description="DaeMon: decoupled dual-queue partitioning + adaptive "
                "selection unit + congestion-triggered link compression"))

# ablation compositions (paper's technique-by-technique decomposition):
# daemon_nocomp / daemon_fifo / daemon_fixed_gran each remove exactly one
# technique; both_dualq keeps only decoupled movement + partitioning
register_policy(MovementPolicy(
    name="daemon_nocomp", granularity="adaptive", partitioning="dual",
    compression="off", throttle=True,
    description="daemon minus link compression"))
register_policy(MovementPolicy(
    name="daemon_fifo", granularity="adaptive", partitioning="fifo",
    compression="link", throttle=True,
    description="daemon minus bandwidth partitioning (lines queue behind "
                "pages on one FIFO)"))
register_policy(MovementPolicy(
    name="daemon_fixed_gran", granularity="both", partitioning="dual",
    compression="link", throttle=True,
    description="daemon minus adaptive selection: every triggering miss "
                "moves both granularities; coalesced misses never race "
                "extra lines"))
register_policy(MovementPolicy(
    name="both_dualq", granularity="both", partitioning="dual",
    compression="off", throttle=False,
    description="decoupled movement + partitioning alone: line+page for "
                "every miss on the dual-queue link, first arrival wins"))
register_policy(MovementPolicy(
    name="daemon_fabfifo", granularity="adaptive", partitioning="dual",
    compression="link", throttle=True, fabric="fifo",
    description="daemon with FIFO switch ports: dual-queue protection at "
                "the endpoint NICs only (fabric-partitioning ablation, "
                "§2.11; identical to daemon on topology=None/direct)"))
register_policy(MovementPolicy(
    name="page_dualq", granularity="page", partitioning="dual",
    compression="off", throttle=False,
    description="page scheme on the dual-queue link (no line traffic, so "
                "effectively the FIFO page scheme — a null ablation)"))

# serving-pool compositions (DESIGN.md §2.9): per-CC heterogeneous policy
# assignment for disaggregated prefill/decode routers.  Prefill-pool CCs
# stream page-dense KV-fill bursts — a low line share lets the bulk class
# drain; decode-pool CCs are latency-critical — a high line share protects
# their critical lines against the prefill pool's page bursts on the
# shared downlink (SharedHeteroLink uses the max share among dual flows).
register_policy(MovementPolicy(
    name="daemon_prefill", granularity="adaptive", partitioning="dual",
    compression="link", throttle=True, line_share=0.35,
    description="daemon tuned for prefill-pool CCs: bulk-friendly low "
                "line share"))
register_policy(MovementPolicy(
    name="daemon_decode", granularity="adaptive", partitioning="dual",
    compression="link", throttle=True, line_share=0.75,
    description="daemon tuned for decode-pool CCs: latency-protecting "
                "high line share"))
