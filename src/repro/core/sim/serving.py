"""Request-level disaggregated LLM serving layer (DESIGN.md §2.9).

The paper's robustness claim is evaluated closed-loop — every CC replays
one fixed stream end to end.  Production disaggregated-memory deployments
live or die by different numbers: request tail latency (p50/p99) and
goodput under load.  This module stitches the captured Pallas-kernel
streams (DESIGN.md §2.8) into *requests* and schedules them onto the
multi-CC simulator through the existing contended downlink/uplink
machinery:

- A :class:`RequestSpec` is one LLM inference request: a prefill phase
  (one ``prefill_workload`` burst of ``prefill_accesses``) followed by
  ``decode_steps`` decode phases (``decode_accesses`` each), every phase a
  deterministic ``replay_slice`` of the workload's captured trace (the
  per-request seed rotates the replay offset, so requests touch
  overlapping-but-shifted KV pages).
- Arrivals are open-loop: seeded exponential inter-arrival draws at
  ``offered_load`` requests per Mcycle.  The arrival process is a pure
  function of the cell seed — identical across schemes and sweep workers.
- A registered :class:`RouterPolicy` assigns each request's phases to CCs:
  ``round_robin`` and ``least_loaded`` keep a request on one CC;
  ``disagg_prefill`` splits the CCs into a prefill pool and a decode pool
  (vLLM-style prefill/decode disaggregation).  The KV handoff is modeled
  organically: the decode CC's local page cache is cold for the pages the
  prefill CC just filled, so its first decode slices re-fetch the
  MC-resident KV pages through the contended links.
- Per-CC heterogeneous :class:`~repro.core.sim.policy.MovementPolicy`
  (``serving_prefill_policy`` / ``serving_decode_policy``) lets each pool
  run its own movement composition; the engine's SharedHeteroLink
  arbitrates the mixed flows on the shared per-MC downlinks.

Each CC offers ``cfg.n_cores`` request slots (one phase occupies one
core); excess work queues FIFO per CC.  A phase completes when its core
has issued the whole slice and its outstanding reads drained (write fills
land asynchronously — write-release semantics).  Per-request completion
cycles roll up into the Metrics extensions ``request_p50`` /
``request_p99`` / ``goodput`` plus a full per-request record list.

With ``cfg.mc_capacity_pages`` set (§2.13), the serving run's tenants
contend for the finite memory pool too: every phase's working set is
allocated through the shared :class:`~repro.core.sim.memside.MemsideState`,
so skewed '+'-mixes (one tenant's KV pages crowding out another's) show up
as cross-MC spills and cold-resident evictions in ``mc_spills`` /
``mc_evictions`` — no serving-layer code is capacity-aware; the pressure
flows through the same engine hooks the closed-loop model uses.

Everything is deterministic given (cfg, scheme, seed): serial runs,
pooled sweep workers, and repeated processes produce bit-identical
per-request completion cycles (locked by tests/test_serving.py).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sim.config import Metrics, SimConfig
from repro.core.sim.engine import Core, Simulator
from repro.core.sim.policy import get_policy
from repro.core.sim.trace import Trace, generate

# footprint handed to synthetic phase workloads (captured kernels ignore
# it: their tiling geometry is authoritative); matches run_one's default
PHASE_FOOTPRINT = 16 << 20

_ARRIVAL_SALT = 0x5EED  # decorrelates arrival draws from trace seeds


# --------------------------------------------------------------------------
# request model
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class RequestSpec:
    """One inference request: phases[0] is the prefill burst, phases[1:]
    are the decode steps; ``arrival`` is its open-loop arrival cycle."""

    rid: int
    arrival: float
    phases: Tuple[Trace, ...]


@dataclass
class RequestRecord:
    """Mutable per-request lifecycle record (rolled into Metrics.requests).
    Times are NaN until the corresponding event happens; CC indices are -1
    until assigned."""

    rid: int
    arrival: float
    prefill_cc: int = -1
    decode_cc: int = -1
    t_start: float = math.nan  # prefill began issuing on a core
    t_prefill_done: float = math.nan
    t_done: float = math.nan  # last decode phase drained

    @property
    def completed(self) -> bool:
        return not math.isnan(self.t_done)

    @property
    def arrived(self) -> bool:
        return self.prefill_cc >= 0

    @property
    def latency(self) -> float:
        return self.t_done - self.arrival

    def as_dict(self) -> dict:
        return {
            "rid": self.rid,
            "arrival": self.arrival,
            "prefill_cc": self.prefill_cc,
            "decode_cc": self.decode_cc,
            "t_start": self.t_start,
            "t_prefill_done": self.t_prefill_done,
            "t_done": self.t_done,
            "latency": self.latency,
        }


def request_arrivals(cfg: SimConfig, seed: int) -> np.ndarray:
    """Open-loop Poisson arrival cycles: seeded exponential inter-arrival
    draws at ``offered_load`` requests per Mcycle.  A pure function of
    (cfg, seed) — schemes and sweep workers see identical arrivals."""
    rng = np.random.default_rng((seed, _ARRIVAL_SALT))
    gaps = rng.exponential(scale=1e6 / cfg.offered_load, size=cfg.n_requests)
    return np.cumsum(gaps)


def build_requests(cfg: SimConfig, seed: int) -> List[RequestSpec]:
    """Materialize the request set: per-request phase traces via the
    registered workload generators (captured kernels route through
    ``replay_slice``, so the per-request seed rotates the replay offset —
    each request's KV pages overlap-but-shift against its neighbors')."""
    arrivals = request_arrivals(cfg, seed)
    reqs = []
    for rid in range(cfg.n_requests):
        base = seed + 101 * rid
        phases = [generate(cfg.prefill_workload, seed=base,
                           footprint=PHASE_FOOTPRINT, n=cfg.prefill_accesses)]
        for k in range(cfg.decode_steps):
            phases.append(generate(cfg.decode_workload, seed=base + 7 * (k + 1),
                                   footprint=PHASE_FOOTPRINT,
                                   n=cfg.decode_accesses))
        reqs.append(RequestSpec(rid=rid, arrival=float(arrivals[rid]),
                                phases=tuple(phases)))
    return reqs


# --------------------------------------------------------------------------
# router registry
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class RouterPolicy:
    """One request-routing policy.  ``pools`` returns (prefill_pool,
    decode_pool) CC index tuples; ``pick`` chooses a CC from a pool given
    the current per-CC loads (busy cores + queued phases).  ``handoff``
    routers move a request to the decode pool after prefill (disjoint
    pools); non-handoff routers keep all phases on the arrival CC."""

    name: str
    description: str = ""
    handoff: bool = False

    def pools(self, n_ccs: int, cfg: SimConfig) -> Tuple[Tuple[int, ...],
                                                         Tuple[int, ...]]:
        ccs = tuple(range(n_ccs))
        return ccs, ccs

    def pick(self, pool: Sequence[int], loads: Sequence[int], rid: int) -> int:
        raise NotImplementedError


@dataclass(frozen=True)
class RoundRobinRouter(RouterPolicy):
    def pick(self, pool: Sequence[int], loads: Sequence[int], rid: int) -> int:
        return pool[rid % len(pool)]


@dataclass(frozen=True)
class LeastLoadedRouter(RouterPolicy):
    def pick(self, pool: Sequence[int], loads: Sequence[int], rid: int) -> int:
        return min(pool, key=lambda c: (loads[c], c))


@dataclass(frozen=True)
class DisaggPrefillRouter(RouterPolicy):
    handoff: bool = True

    def pools(self, n_ccs: int, cfg: SimConfig) -> Tuple[Tuple[int, ...],
                                                         Tuple[int, ...]]:
        if n_ccs < 2:
            raise ValueError(
                f"router {self.name!r} needs n_ccs >= 2 (one CC per pool); "
                f"got n_ccs={n_ccs}")
        n_p = min(n_ccs - 1,
                  max(1, round(n_ccs * cfg.serving_prefill_frac)))
        ccs = tuple(range(n_ccs))
        return ccs[:n_p], ccs[n_p:]

    def pick(self, pool: Sequence[int], loads: Sequence[int], rid: int) -> int:
        return min(pool, key=lambda c: (loads[c], c))


_ROUTERS: Dict[str, RouterPolicy] = {}


def register_router(router: RouterPolicy, *, overwrite: bool = False) -> RouterPolicy:
    """Register a :class:`RouterPolicy` under its ``name`` (mirrors the
    policy/workload registries; duplicate names raise unless overwrite)."""
    if not isinstance(router, RouterPolicy):
        raise TypeError(f"register_router needs a RouterPolicy, got {router!r}")
    if router.name in _ROUTERS and not overwrite:
        raise ValueError(
            f"router {router.name!r} already registered "
            f"(pass overwrite=True to replace)")
    _ROUTERS[router.name] = router
    return router


def unregister_router(name: str) -> None:
    _ROUTERS.pop(name, None)


def get_router(name) -> RouterPolicy:
    """Resolve a router by name; unknown names fail fast listing choices."""
    if isinstance(name, RouterPolicy):
        return name
    r = _ROUTERS.get(name)
    if r is None:
        raise KeyError(
            f"unknown router {name!r}; registered routers: "
            f"{', '.join(available_routers())}")
    return r


def available_routers() -> Tuple[str, ...]:
    return tuple(_ROUTERS)


register_router(RoundRobinRouter(
    name="round_robin",
    description="rid % pool: all phases on the arrival CC"))
register_router(LeastLoadedRouter(
    name="least_loaded",
    description="fewest busy+queued phases (ties: lowest CC index); all "
                "phases on the arrival CC"))
register_router(DisaggPrefillRouter(
    name="disagg_prefill",
    description="prefill-specialized and decode-specialized CC pools "
                "(serving_prefill_frac split); decode phases re-fetch the "
                "MC-resident KV pages cold"))


# --------------------------------------------------------------------------
# scheduler
# --------------------------------------------------------------------------


def _empty_trace() -> Trace:
    z = np.zeros(0, np.int64)
    return (z, z, np.zeros(0, bool))


class ServingScheduler:
    """Open-loop request scheduler over one :class:`Simulator` instance.

    Cores bootstrap with empty traces and report idle at t=0; arrivals are
    engine events; the engine's ``on_core_idle`` hook drives phase
    transitions (next decode step, pool handoff, or request completion).
    All scheduling state is deterministic given (cfg, scheme, seed)."""

    def __init__(self, cfg: SimConfig, scheme, *, seed: int = 0):
        if cfg.serving_router is None:
            raise ValueError("ServingScheduler needs cfg.serving_router set "
                             "(see available_routers())")
        self.cfg = cfg
        self.router = get_router(cfg.serving_router)
        n_ccs = max(1, cfg.n_ccs)
        self.prefill_pool, self.decode_pool = self.router.pools(n_ccs, cfg)
        self.requests = build_requests(cfg, seed)
        self.records = [RequestRecord(rid=r.rid, arrival=r.arrival)
                        for r in self.requests]

        base_pol = get_policy(scheme)
        pre_over, dec_over = cfg.serving_prefill_policy, cfg.serving_decode_policy
        if (pre_over or dec_over) and not self.router.handoff:
            raise ValueError(
                "per-pool policy overrides (serving_prefill_policy / "
                "serving_decode_policy) need a disaggregated router with "
                f"disjoint pools; router {self.router.name!r} shares CCs")
        pre_ctrl = cfg.serving_prefill_controller
        dec_ctrl = cfg.serving_decode_controller
        if (pre_ctrl or dec_ctrl) and not self.router.handoff:
            raise ValueError(
                "per-pool controller overrides (serving_prefill_controller /"
                " serving_decode_controller) need a disaggregated router "
                f"with disjoint pools; router {self.router.name!r} shares CCs")
        pset = set(self.prefill_pool)
        if pre_over or dec_over or pre_ctrl or dec_ctrl:
            pp = get_policy(pre_over) if pre_over else base_pol
            dp = get_policy(dec_over) if dec_over else base_pol
            if pre_ctrl:
                pp = pp.with_(controller=pre_ctrl)
            if dec_ctrl:
                dp = dp.with_(controller=dec_ctrl)
            policies: object = [pp if c in pset else dp for c in range(n_ccs)]
        else:
            policies = base_pol

        # per-CC workload labels drive each CC's compressibility model:
        # disaggregated pools are labeled by their phase, shared pools by
        # the decode workload (decode slices dominate the request count)
        if self.router.handoff:
            cc_workloads = [cfg.prefill_workload if c in pset
                            else cfg.decode_workload for c in range(n_ccs)]
        else:
            cc_workloads = [cfg.decode_workload] * n_ccs
        workload = "+".join(cc_workloads) if n_ccs > 1 else cc_workloads[0]

        # one shared per-CC footprint spanning every phase trace: requests
        # replay overlapping windows of the same captured streams, so the
        # local page cache models a shared (KV-page) working set
        fp = max(int(tr[1].max()) + 64
                 for r in self.requests for tr in r.phases)
        groups = [[_empty_trace() for _ in range(cfg.n_cores)]
                  for _ in range(n_ccs)]
        self.sim = Simulator(cfg, policies, groups, workload=workload,
                             seed=seed, footprints=[fp] * n_ccs)
        self.sim.on_core_idle = self._on_idle

        self._idle: List[List[Core]] = [[] for _ in range(n_ccs)]
        self._queues: List[deque] = [deque() for _ in range(n_ccs)]
        self._core_job: Dict[int, Tuple[RequestSpec, int]] = {}

    # -- state --
    def _loads(self) -> List[int]:
        n_cores = self.cfg.n_cores
        return [(n_cores - len(self._idle[c])) + len(self._queues[c])
                for c in range(len(self._idle))]

    # -- scheduling --
    def _arrive(self, req: RequestSpec, t: float):
        rec = self.records[req.rid]
        cc = self.router.pick(self.prefill_pool, self._loads(), req.rid)
        rec.prefill_cc = cc
        self._submit(cc, req, 0, t)

    def _submit(self, cc: int, req: RequestSpec, phase: int, t: float):
        if self._idle[cc]:
            self._start(self._idle[cc].pop(), req, phase, t)
        else:
            self._queues[cc].append((req, phase))

    def _start(self, core: Core, req: RequestSpec, phase: int, t: float):
        rec = self.records[req.rid]
        if phase == 0 and math.isnan(rec.t_start):
            rec.t_start = t
        self._core_job[core.cid] = (req, phase)
        gaps, addrs, writes = req.phases[phase]
        core.gaps = gaps
        core.addrs = addrs >> 6  # byte addrs -> line addrs (as Simulator)
        core.writes = writes
        core.idx = 0
        core.draining = False
        self.sim.eng.at(t, lambda tt, c=core: self.sim.core_step(c, tt))

    def _park(self, core: Core, t: float):
        q = self._queues[core.cc]
        if q:
            req, phase = q.popleft()
            self._start(core, req, phase, t)
            return
        lst = self._idle[core.cc]
        if core not in lst:
            lst.append(core)

    def _on_idle(self, core: Core, t: float):
        job = self._core_job.pop(core.cid, None)
        if job is None:  # bootstrap idle (empty initial trace)
            self._park(core, t)
            return
        req, phase = job
        rec = self.records[req.rid]
        last = phase == len(req.phases) - 1
        if phase == 0:
            rec.t_prefill_done = t
        if last:
            rec.t_done = t
            self._park(core, t)
            return
        if phase == 0 and self.router.handoff:
            # prefill done: free the prefill slot, hand the request to the
            # decode pool (its local cache is cold for the KV pages — the
            # handoff cost is the re-fetch through the contended links)
            self._park(core, t)
            cc = self.router.pick(self.decode_pool, self._loads(), req.rid)
            rec.decode_cc = cc
            self._submit(cc, req, 1, t)
            return
        if phase == 0:
            rec.decode_cc = core.cc
        self._start(core, req, phase + 1, t)

    # -- run / rollup --
    def run(self) -> Metrics:
        eng = self.sim.eng
        for req in self.requests:
            eng.at(req.arrival, lambda t, r=req: self._arrive(r, t))
        m = self.sim.run(until=self.cfg.serving_horizon)
        self._rollup(m)
        return m

    def _rollup(self, m: Metrics):
        done = [rec for rec in self.records if rec.completed]
        m.requests_offered = self.cfg.n_requests
        m.requests_completed = len(done)
        if done:
            lats = np.array([rec.latency for rec in done])
            m.request_p50 = float(np.percentile(lats, 50))
            m.request_p99 = float(np.percentile(lats, 99))
        makespan = max(m.cycles, 0.0)
        m.goodput = len(done) / makespan * 1e6 if makespan > 0 else 0.0
        m.requests = [rec.as_dict() for rec in self.records]


def serve_one(cfg: SimConfig, scheme, *, seed: int = 0) -> Metrics:
    """One open-loop serving cell (the ``run_one`` of §2.9): build the
    request set, schedule it through ``cfg.serving_router``, and return
    Metrics with the request-level rollup populated."""
    return ServingScheduler(cfg, scheme, seed=seed).run()
