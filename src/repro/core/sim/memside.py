"""Memory-side resident state (DESIGN.md §2.13): allocation, placement,
finite per-MC capacity, and hot-page dynamics.

The paper evaluates DaeMon against an *infinite passive* remote address
space: a page lives forever at the MC a pure function of its address
picks (``engine.mc_place``).  Real disaggregated pools have finite
per-module capacity, allocation/placement policy, and hot-page churn —
the dominant open problems in memory-pool management (Maruf & Chowdhury;
Wang et al.).  This module grows the static ``mc_interleave`` axis into
that subsystem:

- A ``@register_placement`` registry of first-class placement policies.
  The legacy modes ``page`` / ``hash`` / ``single`` re-register as
  compositions of the same arithmetic ``engine.mc_place`` uses (kept in
  lockstep by tests), joined by ``first_touch`` (NUMA-style owning-CC
  affinity) and ``capacity_aware`` (least-loaded at allocation time).
- :class:`MemsideState`: one per-cell state object holding the page
  table (resident MC per (cc, page)), a slab/first-fit allocator per MC
  (``SimConfig.mc_capacity_pages`` slots), cross-MC spill when a module
  fills (charged as extra fabric hops on every transfer touching the
  spilled page), eviction of the coldest resident when the whole pool is
  full, and an access-frequency tracker that raises a promotion signal
  for hot still-remote pages (the engines turn it into a page migration
  toward the owning CC, throttled by the controller's backlog signal).

Bit-parity contract: ``make_memside`` returns ``None`` for the legacy
model (``mc_capacity_pages=None`` and a legacy placement) and the
engines then keep their original expressions untouched — the committed
GOLD/GOLD_MCC goldens stay bit-identical.  When active, BOTH engines
drive the *same* :class:`MemsideState` instance shape at the same event
points with the same arguments (the §2.12 observe/decide discipline:
``touch`` mutates, ``peek`` is pure), so batch==python parity holds by
construction rather than by transcription.

This is a leaf module (stdlib only): ``config.py`` imports it for
fail-fast ``mc_interleave`` validation and the engines import it for the
state object, with no import cycles.
"""
from __future__ import annotations

from dataclasses import dataclass
from heapq import heappop, heappush
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# the legacy static modes: with mc_capacity_pages=None these keep the
# engines on their original mc_place() fast path (golden bit-parity)
LEGACY_PLACEMENTS = ("page", "hash", "single")


# --------------------------------------------------------------------------
# placement registry
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class PlacementPolicy:
    """One registered placement policy.  ``home(cc, page, n_mcs, occ)``
    picks the page's home MC at allocation time; ``occ`` is the live
    per-MC allocated-page count (read-only — how ``capacity_aware``
    implements least-loaded).  ``allocator`` is the short slot-selection
    label shown by ``run.py --list``."""

    name: str
    allocator: str
    description: str = ""
    home: Callable[[int, int, int, Sequence[int]], int] = None

    def __call__(self, cc: int, page: int, n_mcs: int,
                 occ: Sequence[int]) -> int:
        return self.home(cc, page, n_mcs, occ)


_PLACEMENTS: Dict[str, PlacementPolicy] = {}


def register_placement(name: str, *, allocator: str = "static",
                       description: str = "", overwrite: bool = False):
    """Decorator: register ``fn(cc, page, n_mcs, occ) -> mc`` as a
    placement policy (mirrors the policy/workload/controller registries;
    duplicate names raise unless ``overwrite``)."""

    def deco(fn):
        if name in _PLACEMENTS and not overwrite:
            raise ValueError(
                f"placement {name!r} already registered "
                f"(pass overwrite=True to replace)")
        _PLACEMENTS[name] = PlacementPolicy(
            name=name, allocator=allocator, description=description, home=fn)
        return fn

    return deco


def unregister_placement(name: str) -> None:
    _PLACEMENTS.pop(name, None)


def get_placement(name) -> PlacementPolicy:
    """Resolve a placement by name; unknown names fail fast listing
    choices (the config/sweep entry points route through here)."""
    if isinstance(name, PlacementPolicy):
        return name
    p = _PLACEMENTS.get(name)
    if p is None:
        raise KeyError(
            f"unknown placement {name!r}; registered placements: "
            f"{', '.join(available_placements())}")
    return p


def available_placements() -> Tuple[str, ...]:
    return tuple(_PLACEMENTS)


# legacy static modes: the home expressions mirror engine.mc_place arm
# for arm (tests/test_memside.py locks them together) so re-registering
# them here cannot drift from the golden path


@register_placement(
    "page", allocator="static",
    description="round-robin interleave: page % n_mcs (legacy default)")
def _home_page(cc: int, page: int, n_mcs: int, occ: Sequence[int]) -> int:
    return page % n_mcs


@register_placement(
    "hash", allocator="static",
    description="Fibonacci hash of the page number: immune to "
                "power-of-two strides (legacy 'hash')")
def _home_hash(cc: int, page: int, n_mcs: int, occ: Sequence[int]) -> int:
    return (((page * 0x9E3779B1) & 0xFFFFFFFF) >> 7) % n_mcs


@register_placement(
    "single", allocator="static",
    description="everything on MC 0: one-module pool (legacy 'single')")
def _home_single(cc: int, page: int, n_mcs: int, occ: Sequence[int]) -> int:
    return 0


@register_placement(
    "first_touch", allocator="affine",
    description="NUMA-style first touch: a page's home is its owning "
                "CC's affine module (cc % n_mcs) — best locality, worst "
                "balance under skewed tenancy")
def _home_first_touch(cc: int, page: int, n_mcs: int,
                      occ: Sequence[int]) -> int:
    return cc % n_mcs


@register_placement(
    "capacity_aware", allocator="least_loaded",
    description="least-loaded at allocation time: the MC with the "
                "fewest resident pages (ties: lowest index)")
def _home_capacity_aware(cc: int, page: int, n_mcs: int,
                         occ: Sequence[int]) -> int:
    best = 0
    lo = occ[0]
    for j in range(1, n_mcs):
        if occ[j] < lo:
            lo = occ[j]
            best = j
    return best


# --------------------------------------------------------------------------
# per-cell memory-side state
# --------------------------------------------------------------------------


class MemsideState:
    """Resident-page state for one simulation cell, shared by both
    engines (one instance per Simulator / per batch _Frame).

    Determinism: every structure is a dict/list/heap over ints mutated
    only by ``touch`` — which both engines call at the same four
    transfer-issue points (line fetch, daemon line fetch, page send,
    writeback send) in the same event order — so python and batch runs
    stay bit-identical.  ``peek`` is pure (the controller-observation
    hook may be evaluated a different number of times per engine, per
    the §2.12 observe/decide split).
    """

    __slots__ = ("n_mcs", "capacity", "hot_threshold", "switch_lat",
                 "placement", "table", "occ", "resid", "hops", "slot",
                 "free_slots", "spills", "evictions", "promotions")

    def __init__(self, n_mcs: int, placement, capacity: Optional[int],
                 hot_threshold: int, switch_lat: float):
        self.n_mcs = max(1, n_mcs)
        self.placement = get_placement(placement)
        self.capacity = capacity
        self.hot_threshold = max(1, hot_threshold)
        self.switch_lat = float(switch_lat)
        # page table: (cc, page) -> resident MC
        self.table: Dict[Tuple[int, int], int] = {}
        # per-MC allocated-page counts (the placement's 'occ' view)
        self.occ: List[int] = [0] * self.n_mcs
        # per-MC residents in allocation order: (cc, page) -> access count
        # (line fetches since allocation/promotion; the hotness signal)
        self.resid: List[Dict[Tuple[int, int], int]] = [
            {} for _ in range(self.n_mcs)]
        # spilled pages: (cc, page) -> ring hops from home (extra fabric
        # hops charged on every transfer touching the page)
        self.hops: Dict[Tuple[int, int], int] = {}
        # slab bookkeeping (finite capacity only): first-fit slot index
        # per resident, lowest free slot first
        if capacity is None:
            self.slot = None
            self.free_slots = None
        else:
            self.slot = {}
            self.free_slots = [list(range(capacity))
                               for _ in range(self.n_mcs)]
        self.spills = 0      # allocations that landed off their home MC
        self.evictions = 0   # cold residents dropped from a full pool
        self.promotions = 0  # hot-page migrations issued by the engines

    # -- pure reads --
    def peek(self, cc: int, page: int) -> int:
        """Resident MC if allocated, else the placement's would-be home.
        Pure: safe from controller-observation paths that the two
        engines evaluate a different number of times."""
        if self.n_mcs <= 1:
            return 0
        mc = self.table.get((cc, page))
        if mc is None:
            return self.placement.home(cc, page, self.n_mcs, self.occ)
        return mc

    def resident_mc(self, cc: int, page: int) -> Optional[int]:
        return self.table.get((cc, page))

    # -- the single mutation point --
    def touch(self, cc: int, page: int,
              kind: str) -> Tuple[int, float, bool]:
        """Resolve the resident MC for one transfer and update state.

        ``kind`` is ``'line'`` (line fetch: counts toward hotness),
        ``'page'`` (page migration: resets the hotness count — the page
        just moved toward the CC), or ``'wb'`` (writeback: re-allocates
        an evicted backing page, no hotness change).  Returns ``(mc,
        extra_lat, promote)``: the resident MC, the extra fabric-hop
        latency for spilled residents (ring hops x switch_lat), and the
        hot-page promotion signal (finite capacity only; fires once per
        hot_threshold line fetches, then re-arms)."""
        key = (cc, page)
        mc = self.table.get(key)
        if mc is None:
            mc = self._alloc(key)
        promote = False
        res = self.resid[mc]
        if kind == "line":
            n = res[key] + 1
            if self.capacity is not None and n >= self.hot_threshold:
                res[key] = 0
                promote = True
            else:
                res[key] = n
        elif kind == "page":
            res[key] = 0
        h = self.hops.get(key)
        return mc, (h * self.switch_lat if h else 0.0), promote

    # -- allocation / spill / eviction --
    def _alloc(self, key: Tuple[int, int]) -> int:
        cc, page = key
        n = self.n_mcs
        home = (0 if n <= 1
                else self.placement.home(cc, page, n, self.occ))
        cap = self.capacity
        mc = home
        if cap is not None and self.occ[home] >= cap:
            # first-fit ring scan from the home module upward
            mc = -1
            for d in range(1, n):
                j = home + d
                if j >= n:
                    j -= n
                if self.occ[j] < cap:
                    mc = j
                    break
            if mc < 0:
                # whole pool full: evict the coldest resident at home
                self._evict_coldest(home)
                mc = home
            else:
                self.spills += 1
        self.table[key] = mc
        self.occ[mc] += 1
        self.resid[mc][key] = 0
        if self.free_slots is not None:
            self.slot[key] = heappop(self.free_slots[mc])
        if mc != home:
            d = mc - home
            if d < 0:
                d += n
            self.hops[key] = d if d <= n - d else n - d  # ring distance
        return mc

    def _evict_coldest(self, mc: int) -> Tuple[int, int]:
        """Drop the coldest resident (lowest access count; allocation
        order breaks ties) from MC ``mc``, freeing its slab slot.  The
        page's next transfer re-allocates it fresh."""
        res = self.resid[mc]
        victim = None
        best = -1
        for k, cnt in res.items():
            if victim is None or cnt < best:
                victim = k
                best = cnt
        if victim is None:
            raise RuntimeError(f"evict from empty MC {mc}")
        del res[victim]
        del self.table[victim]
        self.occ[mc] -= 1
        self.hops.pop(victim, None)
        if self.free_slots is not None:
            heappush(self.free_slots[mc], self.slot.pop(victim))
        self.evictions += 1
        return victim


def make_memside(n_mcs: int, placement: str, capacity: Optional[int],
                 hot_threshold: int, switch_lat: float
                 ) -> Optional[MemsideState]:
    """Build the per-cell state, or ``None`` for the legacy infinite
    model (a legacy placement and no capacity) — the engines then keep
    their original mc_place expressions untouched, preserving the
    committed goldens bit for bit."""
    if capacity is None and placement in LEGACY_PLACEMENTS:
        return None
    return MemsideState(n_mcs, placement, capacity, hot_threshold,
                        switch_lat)
