from repro.models import model, nn

__all__ = ["model", "nn"]
