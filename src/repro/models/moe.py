"""Mixture-of-Experts FFN: top-k routing with capacity-bounded, sort-free
scatter dispatch, processed in token groups (bounded memory), experts sharded
over the ``model`` mesh axis (expert parallelism = TP axis).

Dispatch is the classic positions-via-cumsum scheme: for every (token, k)
assignment we compute its rank within its expert with a cumsum over a one-hot
(Tg*K, E) matrix, drop assignments past the expert capacity C (out-of-range
scatter indices with ``mode="drop"``), run the expert FFNs as a single
(E, C, d) x (E, d, f) einsum — this is the op GSPMD turns into the expert
all-to-all when tokens are data-sharded and experts model-sharded, i.e. the
"page-granularity" traffic class of the serving/training fabric that DaeMon
compresses (core/movement).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import nn
from repro.models.nn import ParamSpec, logical_constraint


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    # experts take the TP ("model") axis (EP=TP); the per-expert d_ff stays
    # unsharded — mapping it to "model" too would double-book the axis.
    specs = {
        "router": ParamSpec((d, e), ("embed", "experts_router")),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", None)),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", None)),
        "w_down": ParamSpec((e, f, d), ("experts", None, "embed")),
    }
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        specs.update(
            shared_gate=ParamSpec((d, fs), ("embed", "mlp")),
            shared_up=ParamSpec((d, fs), ("embed", "mlp")),
            shared_down=ParamSpec((fs, d), ("mlp", "embed")),
        )
    return specs


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _dispatch_group(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (Tg, d) -> (y: (Tg, d), aux_loss: scalar)."""
    tg, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = _capacity(tg, cfg)

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (Tg, E) f32
    gates, idx = jax.lax.top_k(probs, k)  # (Tg, K)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)

    e_flat = idx.reshape(-1)  # (Tg*K,)
    tok_flat = jnp.repeat(jnp.arange(tg), k)
    gate_flat = gates.reshape(-1)

    # rank within expert via cumsum over one-hot
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)  # (Tg*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1  # rank of each assignment
    pos = jnp.take_along_axis(pos_in_e, e_flat[:, None], axis=1)[:, 0]
    pos = jnp.where(pos < cap, pos, cap)  # cap -> out of range -> dropped

    xs = jnp.zeros((e, cap, d), x.dtype)
    xs = xs.at[e_flat, pos].set(x[tok_flat], mode="drop")
    # keep the scattered dispatch buffer REPLICATED: scattering into an
    # expert-sharded buffer makes GSPMD materialize full-size masked updates
    # per shard (measured 6.6 GB/group on dbrx — §Perf C1); the buffer itself
    # is ~126 MB and the expert einsum below induces the E-sharding.
    xs = logical_constraint(xs, None, None, None)

    xg = jnp.einsum("ecd,edf->ecf", xs, p["w_gate"].astype(x.dtype))
    xu = jnp.einsum("ecd,edf->ecf", xs, p["w_up"].astype(x.dtype))
    ys = jnp.einsum("ecf,efd->ecd", nn.silu(xg) * xu, p["w_down"].astype(x.dtype))

    y_tok = ys.at[e_flat, pos].get(mode="fill", fill_value=0)  # (Tg*K, d)
    keep = (pos < cap).astype(x.dtype)
    y_tok = y_tok * (gate_flat.astype(x.dtype) * keep)[:, None]
    y = jnp.sum(y_tok.reshape(tg, k, d), axis=1)
    return y, aux


def apply_moe(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). Token groups bound dispatch memory."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    t = xf.shape[0]
    g = max(1, t // max(cfg.moe_group_size, 1))
    while t % g:
        g -= 1
    xg = xf.reshape(g, t // g, d)

    def body(carry, xi):
        yi, aux = _dispatch_group(p, xi, cfg)
        return carry + aux, yi

    aux_total, yg = jax.lax.scan(body, jnp.zeros((), jnp.float32), xg)
    y = yg.reshape(b, s, d)

    if cfg.num_shared_experts:
        y = y + nn.swiglu(x, p["shared_gate"], p["shared_up"], p["shared_down"])
    return y, aux_total / g
