"""Decoder-only transformer trunk covering the dense archs (minicpm, danube,
stablelm, qwen3), the VLM backbone (internvl2 — stub ViT prefix), and the MoE
archs (deepseek-v2-lite with MLA, dbrx) via segment composition.

Layers are grouped into *segments* of uniform structure; each segment's
parameters are stacked on a leading ``layers`` axis and executed with
``jax.lax.scan`` (keeps HLO size O(1) in depth — an 80L x d8192 model lowers
in seconds).  Caches are stacked the same way and co-scanned at decode.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models import nn
from repro.models.nn import ParamSpec, logical_constraint

PyTree = Any


# --------------------------------------------------------------------------
# segments
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Segment:
    name: str
    n_layers: int
    is_moe: bool


def segments(cfg: ModelConfig) -> List[Segment]:
    if cfg.family in ("dense", "vlm"):
        return [Segment("seg0", cfg.num_layers, False)]
    if cfg.family == "moe":
        segs = []
        if cfg.first_dense_layers:
            segs.append(Segment("seg0", cfg.first_dense_layers, False))
        segs.append(Segment(f"seg{len(segs)}", cfg.num_layers - cfg.first_dense_layers, True))
        return segs
    raise ValueError(f"transformer trunk does not build family {cfg.family!r}")


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    if cfg.attn_kind == "mla":
        h = cfg.num_heads
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        s = {
            "wq": ParamSpec((d, h * qk), ("embed", "heads")),
            "w_dkv": ParamSpec((d, cfg.kv_lora_rank + cfg.qk_rope_dim), ("embed", "lora")),
            "kv_norm": ParamSpec((cfg.kv_lora_rank,), (None,), "ones"),
            "w_uk": ParamSpec((cfg.kv_lora_rank, h * cfg.qk_nope_dim), ("lora", "heads")),
            "w_uv": ParamSpec((cfg.kv_lora_rank, h * cfg.v_head_dim), ("lora", "heads")),
            "wo": ParamSpec((h * cfg.v_head_dim, d), ("heads", "embed")),
        }
        return s
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = {
        "wq": ParamSpec((d, h * dh), ("embed", "heads")),
        "wk": ParamSpec((d, kvh * dh), ("embed", "kv_heads")),
        "wv": ParamSpec((d, kvh * dh), ("embed", "kv_heads")),
        "wo": ParamSpec((h * dh, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((dh,), (None,), "ones")
        s["k_norm"] = ParamSpec((dh,), (None,), "ones")
    return s


def mlp_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("embed", "mlp")),
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }


def block_specs(cfg: ModelConfig, is_moe: bool) -> Dict[str, Any]:
    s: Dict[str, Any] = {
        "ln1": ParamSpec((cfg.d_model,), (None,), "ones"),
        "attn": attn_specs(cfg),
        "ln2": ParamSpec((cfg.d_model,), (None,), "ones"),
    }
    s["ffn"] = moe_lib.moe_specs(cfg) if is_moe else mlp_specs(cfg)
    return s


def lm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
        "ln_f": ParamSpec((cfg.d_model,), (None,), "ones"),
    }
    for seg in segments(cfg):
        s[seg.name] = nn.stack_specs(block_specs(cfg, seg.is_moe), seg.n_layers)
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return s


# --------------------------------------------------------------------------
# attention application
# --------------------------------------------------------------------------


def _cache_window(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.attn_kind == "swa":
        return min(cfg.window, seq_len)
    return seq_len


def gqa_qkv(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array,
            *, decode: bool = False):
    b, s, _ = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"].astype(x.dtype)).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"].astype(x.dtype)).reshape(b, s, kvh, dh)
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"].astype(x.dtype)).reshape(b, s, kvh, dh)
    if cfg.qk_norm:
        q = nn.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = nn.rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embed == "rope":
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k = nn.apply_rope(k, positions, cfg.rope_theta)
    if decode:
        # align with the cache sharding (kv_seq / kv_dh per the active rules)
        # so the einsums against the resident cache never re-shard it; the
        # single-token q/k/v are tiny in every layout (§Perf A1).
        q = logical_constraint(q, "act_batch", None, None, "kv_dh")
        k = logical_constraint(k, "act_batch", None, None, "kv_dh")
        v = logical_constraint(v, "act_batch", None, None, "kv_dh")
        return q, k, v
    # train/prefill: q shards over the full `heads` dim; raw k/v keep
    # kv_heads unsharded (often < TP degree) — the repeat inside attention
    # propagates q's head sharding onto the expanded copies.
    q = logical_constraint(q, "act_batch", None, "heads", None)
    k = logical_constraint(k, "act_batch", None, None, None)
    v = logical_constraint(v, "act_batch", None, None, None)
    return q, k, v


def gqa_attn_forward(
    cfg: ModelConfig,
    p,
    x: jax.Array,
    positions: jax.Array,
    *,
    make_cache: bool = False,
    causal: bool = True,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full-sequence attention (train / prefill)."""
    q, k, v = gqa_qkv(cfg, p, x, positions)
    window = cfg.window if cfg.attn_kind == "swa" else 0
    o = nn.attention(q, k, v, causal=causal, window=window, chunk=cfg.attn_chunk)
    out = jnp.einsum(
        "bsk,kd->bsd", o.reshape(o.shape[0], o.shape[1], -1), p["wo"].astype(x.dtype)
    )
    cache = None
    if make_cache:
        w = _cache_window(cfg, k.shape[1])
        s = k.shape[1]
        if w < s:  # ring-buffer extraction: keep last w positions at slot p % w
            sl = (jnp.arange(w) + (s - w)) % w
            kc = jnp.zeros((k.shape[0], w, *k.shape[2:]), k.dtype).at[:, sl].set(k[:, s - w :])
            vc = jnp.zeros((v.shape[0], w, *v.shape[2:]), v.dtype).at[:, sl].set(v[:, s - w :])
        else:
            kc, vc = k, v
        cache = {"k": kc, "v": vc}
    return out, cache


def gqa_attn_decode(
    cfg: ModelConfig, p, x: jax.Array, cache: Dict[str, jax.Array], pos: jax.Array
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode against a (ring) KV cache. x: (B, 1, d), pos scalar."""
    positions = pos[None] if pos.ndim == 0 else pos
    q, k_new, v_new = gqa_qkv(cfg, p, x, positions, decode=True)
    w = cache["k"].shape[1]
    slot = pos % w
    k = cache["k"].at[:, slot].set(k_new[:, 0])
    v = cache["v"].at[:, slot].set(v_new[:, 0])

    if cfg.attn_kind == "swa":
        # ring buffer: slot i holds absolute position pos - ((pos - i) mod w);
        # everything resident is inside the window by construction.
        kv_positions = pos - jnp.mod(pos - jnp.arange(w), w)
        valid = kv_positions >= 0
        o = _decode_attn_abs(cfg, q, k, v, kv_positions, valid)
    else:
        o = nn.attention(
            q, k, v, causal=False, window=0, chunk=cfg.attn_chunk, kv_len=pos + 1
        )
    out = jnp.einsum("bsk,kd->bsd", o.reshape(o.shape[0], 1, -1), p["wo"].astype(x.dtype))
    return out, {"k": k, "v": v}


def _decode_attn_abs(cfg, q, k, v, kv_positions, valid):
    """Decode attention with explicit absolute kv positions (ring buffers)."""
    b, _, h, dh = q.shape
    k = nn.repeat_kv(k, h)
    v = nn.repeat_kv(v, h)
    scores = jnp.einsum(
        "bqhd,bshd->bhs", q, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    scores = jnp.where(valid[None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum(
        "bhs,bshd->bhd", probs.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return o[:, None].astype(q.dtype)


# ---------------------------- MLA (deepseek) -------------------------------


def mla_project_q(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array):
    b, s, _ = x.shape
    h = cfg.num_heads
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"].astype(x.dtype))
    q = q.reshape(b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = nn.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_compress_kv(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array):
    ckv_rope = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    ckv, k_rope = jnp.split(ckv_rope, [cfg.kv_lora_rank], axis=-1)
    ckv = nn.rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = nn.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, k_rope


def mla_attn_forward(
    cfg: ModelConfig, p, x: jax.Array, positions: jax.Array, *, make_cache: bool = False
):
    """Prefill/train MLA: expand compressed kv to per-head K/V (paper-faithful)."""
    b, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = mla_project_q(cfg, p, x, positions)
    ckv, k_rope = mla_compress_kv(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rk->bsk", ckv, p["w_uk"].astype(x.dtype)).reshape(
        b, s, h, cfg.qk_nope_dim
    )
    v = jnp.einsum("bsr,rk->bsk", ckv, p["w_uv"].astype(x.dtype)).reshape(
        b, s, h, cfg.v_head_dim
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, cfg.qk_rope_dim))], axis=-1)
    q = logical_constraint(q, "act_batch", None, "heads", None)
    k = logical_constraint(k, "act_batch", None, "heads", None)
    o = nn.attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    out = jnp.einsum("bsk,kd->bsd", o.reshape(b, s, -1), p["wo"].astype(x.dtype))
    cache = {"ckv": ckv, "krope": k_rope} if make_cache else None
    return out, cache


def mla_attn_decode(cfg: ModelConfig, p, x: jax.Array, cache, pos: jax.Array):
    """Absorbed MLA decode: attention runs in the compressed kv_lora space —
    the cache stays (B, S, R + rope) instead of (B, S, H, 2*dh)."""
    b = x.shape[0]
    h, r = cfg.num_heads, cfg.kv_lora_rank
    positions = pos[None]
    q_nope, q_rope = mla_project_q(cfg, p, x, positions)  # (B,1,H,*)
    ckv_new, krope_new = mla_compress_kv(cfg, p, x, positions)
    ckv = cache["ckv"].at[:, pos].set(ckv_new[:, 0])
    krope = cache["krope"].at[:, pos].set(krope_new[:, 0])

    w_uk = p["w_uk"].reshape(r, h, cfg.qk_nope_dim).astype(x.dtype)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)  # absorb k up-proj
    scores = jnp.einsum("bhr,bsr->bhs", q_abs.astype(jnp.float32), ckv.astype(jnp.float32))
    scores += jnp.einsum(
        "bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32), krope.astype(jnp.float32)
    )
    scores /= jnp.sqrt(jnp.asarray(cfg.qk_nope_dim + cfg.qk_rope_dim, jnp.float32))
    kv_pos = jnp.arange(ckv.shape[1])
    scores = jnp.where((kv_pos <= pos)[None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, ckv.astype(jnp.float32)).astype(x.dtype)
    w_uv = p["w_uv"].reshape(r, h, cfg.v_head_dim).astype(x.dtype)
    o = jnp.einsum("bhr,rhd->bhd", ctx, w_uv)  # absorb v up-proj
    out = jnp.einsum("bk,kd->bd", o.reshape(b, -1), p["wo"].astype(x.dtype))[:, None, :]
    return out, {"ckv": ckv, "krope": krope}


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------


def apply_block(
    cfg: ModelConfig,
    p,
    x: jax.Array,
    positions: jax.Array,
    *,
    is_moe: bool,
    make_cache: bool = False,
    causal: bool = True,
):
    h = nn.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        a, cache = mla_attn_forward(cfg, p["attn"], h, positions, make_cache=make_cache)
    else:
        a, cache = gqa_attn_forward(
            cfg, p["attn"], h, positions, make_cache=make_cache, causal=causal
        )
    x = x + a
    h = nn.rms_norm(x, p["ln2"], cfg.norm_eps)
    if is_moe:
        f, aux = moe_lib.apply_moe(p["ffn"], h, cfg)
    else:
        f = nn.swiglu(h, p["ffn"]["w_gate"], p["ffn"]["w_up"], p["ffn"]["w_down"])
        aux = jnp.zeros((), jnp.float32)
    x = x + f
    x = logical_constraint(x, "act_batch", None, None)
    return x, cache, aux


def apply_block_decode(cfg: ModelConfig, p, x, cache, pos, *, is_moe: bool):
    h = nn.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        a, new_cache = mla_attn_decode(cfg, p["attn"], h, cache, pos)
    else:
        a, new_cache = gqa_attn_decode(cfg, p["attn"], h, cache, pos)
    x = x + a
    h = nn.rms_norm(x, p["ln2"], cfg.norm_eps)
    if is_moe:
        f, _ = moe_lib.apply_moe(p["ffn"], h, cfg)
    else:
        f = nn.swiglu(h, p["ffn"]["w_gate"], p["ffn"]["w_up"], p["ffn"]["w_down"])
    return x + f, new_cache


# --------------------------------------------------------------------------
# trunk forward / prefill / decode over segments
# --------------------------------------------------------------------------


def _remat(fn, cfg: ModelConfig, training: bool):
    if not training or cfg.remat == "nothing":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def trunk_forward(
    cfg: ModelConfig,
    params,
    x: jax.Array,
    positions: jax.Array,
    *,
    training: bool,
    make_cache: bool = False,
    causal: bool = True,
):
    """x: (B, S, d) -> (hidden, cache_by_segment, aux_loss)."""
    aux_total = jnp.zeros((), jnp.float32)
    caches = {}
    for seg in segments(cfg):
        def body(carry, p_l, _seg=seg):
            xx, aux = carry
            xx, cache, a = apply_block(
                cfg, p_l, xx, positions, is_moe=_seg.is_moe,
                make_cache=make_cache, causal=causal,
            )
            return (xx, aux + a), cache

        body = _remat(body, cfg, training)
        (x, aux_total), cache = jax.lax.scan(body, (x, aux_total), params[seg.name])
        if make_cache:
            caches[seg.name] = cache
    return x, caches, aux_total


def trunk_decode(cfg: ModelConfig, params, x, caches, pos):
    new_caches = {}
    for seg in segments(cfg):
        def body(xx, scanned, _seg=seg):
            p_l, cache_l = scanned
            xx, new_cache = apply_block_decode(cfg, p_l, xx, cache_l, pos, is_moe=_seg.is_moe)
            return xx, new_cache

        x, new_cache = jax.lax.scan(body, x, (params[seg.name], caches[seg.name]))
        new_caches[seg.name] = new_cache
    return x, new_caches


# --------------------------------------------------------------------------
# cache specs (abstract shapes for dry-run input_specs)
# --------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    out = {}
    w = _cache_window(cfg, seq_len)
    for seg in segments(cfg):
        if cfg.attn_kind == "mla":
            out[seg.name] = {
                "ckv": ParamSpec((seg.n_layers, batch, seq_len, cfg.kv_lora_rank), ("layers", "act_batch", "kv_seq", "kv_dh")),
                "krope": ParamSpec((seg.n_layers, batch, seq_len, cfg.qk_rope_dim), ("layers", "act_batch", "kv_seq", None)),
            }
        else:
            kvshape = (seg.n_layers, batch, w, cfg.num_kv_heads, cfg.head_dim)
            # which dim takes the TP axis is a RULES decision (runtime/
            # sharding.base_rules cache_shard=): "kv_seq" = split-KV over
            # sequence; "kv_dh" = split over head_dim (local cache writes,
            # tiny partial-sum AR on scores) — see EXPERIMENTS.md §Perf A1.
            axes = ("layers", "act_batch", "kv_seq", None, "kv_dh")
            out[seg.name] = {
                "k": ParamSpec(kvshape, axes),
                "v": ParamSpec(kvshape, axes),
            }
    return out
