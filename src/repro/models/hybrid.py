"""Zamba2-style hybrid: a Mamba2 backbone with ONE shared attention+MLP
block applied every ``attn_every`` SSM blocks.  The shared block reuses a
single parameter set across invocations, with small per-invocation LoRA
adapters on the q/k/v projections (zamba2's parameter-efficiency trick), and
consumes the concatenation [hidden, original-embedding] (2*d_model wide).

Simplifications vs. the HF checkpoint (noted in DESIGN.md): no per-invocation
output linear after the shared block, RMSNorm instead of LayerNorm.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba, nn
from repro.models.nn import ParamSpec


def n_invocations(cfg: ModelConfig) -> int:
    return -(-cfg.num_layers // cfg.attn_every)  # ceil


def _groups(cfg: ModelConfig) -> List[Tuple[int, int]]:
    """[(start_layer, n_layers)] per shared-block invocation."""
    out = []
    for g in range(n_invocations(cfg)):
        lo = g * cfg.attn_every
        hi = min(lo + cfg.attn_every, cfg.num_layers)
        out.append((lo, hi - lo))
    return out


def shared_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d2 = 2 * cfg.d_model
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    r, ninv = cfg.shared_lora_rank, n_invocations(cfg)
    s: Dict[str, Any] = {
        "ln1": ParamSpec((d2,), (None,), "ones"),
        "wq": ParamSpec((d2, h * dh), ("embed", "heads")),
        "wk": ParamSpec((d2, kvh * dh), ("embed", "kv_heads")),
        "wv": ParamSpec((d2, kvh * dh), ("embed", "kv_heads")),
        "wo": ParamSpec((h * dh, cfg.d_model), ("heads", "embed")),
        "ln2": ParamSpec((d2,), (None,), "ones"),
        "w_gate": ParamSpec((d2, cfg.d_ff), ("embed", "mlp")),
        "w_up": ParamSpec((d2, cfg.d_ff), ("embed", "mlp")),
        "w_down": ParamSpec((cfg.d_ff, cfg.d_model), ("mlp", "embed")),
    }
    if r:
        for nme, width in (("q", h * dh), ("k", kvh * dh), ("v", kvh * dh)):
            s[f"lora_{nme}_a"] = ParamSpec((ninv, d2, r), (None, "embed", None), "normal", 0.1)
            s[f"lora_{nme}_b"] = ParamSpec((ninv, r, width), (None, None, "heads"), "zeros")
    return s


def _shared_qkv(cfg: ModelConfig, p, cat: jax.Array, inv: int, positions: jax.Array):
    b, s, _ = cat.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def proj(name, width, heads):
        w = p[f"w{name}"].astype(cat.dtype)
        y = jnp.einsum("bsd,dk->bsk", cat, w)
        if cfg.shared_lora_rank:
            la = p[f"lora_{name}_a"][inv].astype(cat.dtype)
            lb = p[f"lora_{name}_b"][inv].astype(cat.dtype)
            y = y + jnp.einsum("bsr,rk->bsk", jnp.einsum("bsd,dr->bsr", cat, la), lb)
        return y.reshape(b, s, heads, dh)

    q = proj("q", h * dh, h)
    k = proj("k", kvh * dh, kvh)
    v = proj("v", kvh * dh, kvh)
    if cfg.pos_embed == "rope":
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k = nn.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_shared_block(
    cfg: ModelConfig, p, x: jax.Array, emb: jax.Array, inv: int, positions: jax.Array,
    *, make_cache: bool = False,
):
    cat = jnp.concatenate([x, emb], axis=-1)
    hh = nn.rms_norm(cat, p["ln1"], cfg.norm_eps)
    q, k, v = _shared_qkv(cfg, p, hh, inv, positions)
    o = nn.attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    x = x + jnp.einsum("bsk,kd->bsd", o.reshape(*o.shape[:2], -1), p["wo"].astype(x.dtype))
    cat2 = jnp.concatenate([x, emb], axis=-1)
    hh = nn.rms_norm(cat2, p["ln2"], cfg.norm_eps)
    g = jnp.einsum("bsd,df->bsf", hh, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", hh, p["w_up"].astype(x.dtype))
    x = x + jnp.einsum("bsf,fd->bsd", nn.silu(g) * u, p["w_down"].astype(x.dtype))
    cache = {"k": k, "v": v} if make_cache else None
    return x, cache


def apply_shared_block_decode(cfg: ModelConfig, p, x, emb, inv: int, cache, pos):
    """One token. cache: {k, v: (B, S, KVH, dh)} for this invocation."""
    positions = pos[None]
    cat = jnp.concatenate([x, emb], axis=-1)
    hh = nn.rms_norm(cat, p["ln1"], cfg.norm_eps)
    q, k_new, v_new = _shared_qkv(cfg, p, hh, inv, positions)
    k = cache["k"].at[:, pos].set(k_new[:, 0])
    v = cache["v"].at[:, pos].set(v_new[:, 0])
    o = nn.attention(q, k, v, causal=False, chunk=cfg.attn_chunk, kv_len=pos + 1)
    x = x + jnp.einsum("bsk,kd->bsd", o.reshape(*o.shape[:2], -1), p["wo"].astype(x.dtype))
    cat2 = jnp.concatenate([x, emb], axis=-1)
    hh = nn.rms_norm(cat2, p["ln2"], cfg.norm_eps)
    g = jnp.einsum("bsd,df->bsf", hh, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", hh, p["w_up"].astype(x.dtype))
    x = x + jnp.einsum("bsf,fd->bsd", nn.silu(g) * u, p["w_down"].astype(x.dtype))
    return x, {"k": k, "v": v}


# --------------------------------------------------------------------------
# full trunk
# --------------------------------------------------------------------------


def trunk_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "mamba": nn.stack_specs(mamba.mamba2_specs(cfg), cfg.num_layers),
        "shared": shared_block_specs(cfg),
    }


def _mamba_slice(params, lo: int, n: int):
    return jax.tree.map(lambda a: a[lo : lo + n], params)


def trunk_forward(cfg: ModelConfig, params, x, emb, positions, *, training: bool,
                  make_cache: bool = False):
    attn_caches, ssm_caches = [], []
    for inv, (lo, n) in enumerate(_groups(cfg)):
        x, ac = apply_shared_block(
            cfg, params["shared"], x, emb, inv, positions, make_cache=make_cache
        )
        attn_caches.append(ac)

        def body(xx, p_l):
            xx, c = mamba.mamba2_forward(cfg, p_l, xx, make_cache=make_cache)
            return xx, c

        if training and cfg.remat != "nothing":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            ) if cfg.remat == "dots" else jax.checkpoint(body)
        x, sc = jax.lax.scan(body, x, _mamba_slice(params["mamba"], lo, n))
        ssm_caches.append(sc)

    caches = None
    if make_cache:
        caches = {
            "attn": {
                "k": jnp.stack([c["k"] for c in attn_caches]),
                "v": jnp.stack([c["v"] for c in attn_caches]),
            },
            # ssm caches are grouped; keep per-group list keys for re-scan
            **{f"ssm{g}": c for g, c in enumerate(ssm_caches)},
        }
    return x, caches


def trunk_decode(cfg: ModelConfig, params, x, emb, caches, pos):
    new = dict(caches)
    ak = caches["attn"]["k"]
    av = caches["attn"]["v"]
    for inv, (lo, n) in enumerate(_groups(cfg)):
        x, ac = apply_shared_block_decode(
            cfg, params["shared"], x, emb, inv, {"k": ak[inv], "v": av[inv]}, pos
        )
        ak = ak.at[inv].set(ac["k"])
        av = av.at[inv].set(ac["v"])

        def body(xx, scanned):
            p_l, c_l = scanned
            xx, c = mamba.mamba2_decode(cfg, p_l, xx, c_l)
            return xx, c

        x, sc = jax.lax.scan(body, x, (_mamba_slice(params["mamba"], lo, n), caches[f"ssm{inv}"]))
        new[f"ssm{inv}"] = sc
    new["attn"] = {"k": ak, "v": av}
    return x, new


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    ninv = n_invocations(cfg)
    kvshape = (ninv, batch, seq_len, cfg.num_kv_heads, cfg.head_dim)
    axes = (None, "act_batch", "kv_seq", None, "kv_dh")
    out: Dict[str, Any] = {
        "attn": {"k": ParamSpec(kvshape, axes), "v": ParamSpec(kvshape, axes)}
    }
    for g, (lo, n) in enumerate(_groups(cfg)):
        out[f"ssm{g}"] = nn.stack_specs(mamba.mamba2_cache_specs(cfg, batch), n)
    return out
