"""Shared NN machinery: parameter specs (single source of truth for shapes,
logical sharding axes, and initializers), norms, rotary embeddings, and the
memory-bounded chunked attention used by every attention-bearing arch.

Parameters are plain nested dicts of arrays.  Every leaf has a companion
``ParamSpec`` carrying its *logical axis names* — ``runtime/sharding.py`` maps
logical names to mesh axes (``NamedSharding``), which is how the same model
definition runs on 1 CPU device, a 16x16 pod, or the 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names (None = replicated dim)
    init: str = "normal"  # normal | zeros | ones
    scale: float = 1.0  # stddev multiplier for "normal"

    def with_prefix(self, n: int, axis_name: str = "layers") -> "ParamSpec":
        return ParamSpec((n,) + self.shape, (axis_name,) + self.axes, self.init, self.scale)


def spec_tree_map(fn: Callable[[ParamSpec], Any], specs: PyTree) -> PyTree:
    return jax.tree.map(fn, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def stack_specs(specs: PyTree, n: int) -> PyTree:
    """Prepend a scanned ``layers`` dimension to every spec in the tree."""
    return spec_tree_map(lambda s: s.with_prefix(n), specs)


def abstract_params(specs: PyTree, dtype=jnp.float32) -> PyTree:
    return spec_tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs)


def param_axes(specs: PyTree) -> PyTree:
    return spec_tree_map(lambda s: s.axes, specs)


def init_params(specs: PyTree, key: jax.Array, dtype=jnp.float32) -> PyTree:
    """Materialize real parameters (smoke tests / examples)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))

    def one(s: ParamSpec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        if s.init == "s4d":  # A_log init: log(1..N) along the last (state) dim
            row = jnp.log(jnp.arange(1, s.shape[-1] + 1, dtype=jnp.float32))
            return jnp.broadcast_to(row, s.shape).astype(dtype)
        if s.init == "dt_bias":  # softplus^-1 of dt ~ U[1e-3, 1e-1]
            u = jax.random.uniform(k, s.shape, jnp.float32, 1e-3, 1e-1)
            return jnp.log(jnp.expm1(u)).astype(dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        std = s.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [one(s, k) for s, k in zip(leaves, keys)])


def param_bytes(specs: PyTree, bytes_per_el: int = 4) -> int:
    total = 0
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec)):
        total += int(np.prod(s.shape)) * bytes_per_el
    return total


def param_count(specs: PyTree) -> int:
    return sum(
        int(np.prod(s.shape))
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    )


# --------------------------------------------------------------------------
# basic ops
# --------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", silu(g) * u, w_down.astype(x.dtype))


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (..., S, 1, Dh/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq_len: int, d_model: int, offset: jax.Array | int = 0) -> jax.Array:
    pos = (jnp.arange(seq_len) + offset)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)
    ang = pos / jnp.power(10_000.0, dim / d_model)
    pe = jnp.zeros((seq_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe


# --------------------------------------------------------------------------
# attention — memory-bounded chunked softmax attention (the XLA path).
# The Pallas flash kernel (kernels/flash_attention) is the TPU hot path;
# this jnp version is numerically equivalent and is what the dry-run lowers
# (keeps cost_analysis() transparent — see DESIGN.md §3).
# --------------------------------------------------------------------------


def attention(
    q: jax.Array,  # (B, Sq, H, Dh)
    k: jax.Array,  # (B, Skv, KVH, Dh)
    v: jax.Array,  # (B, Skv, KVH, Dv)
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    q_offset: jax.Array | int = 0,
    kv_len: Optional[jax.Array] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Chunked attention. Peak memory O(B*H*chunk*Skv) instead of O(B*H*Sq*Skv).

    ``q_offset``: absolute position of q[:, 0] (decode: the write position).
    ``kv_len``: if given, keys at positions >= kv_len are masked (ring buffers
    / partially-filled caches).
    """
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    kv_pos = jnp.arange(skv)

    if sq <= chunk:
        q_pos = jnp.arange(sq) + q_offset
        return _attn_chunk_masked(
            q, k, v, q_pos, kv_pos, causal=causal, window=window, scale=scale, kv_len=kv_len
        )

    n = sq // chunk
    assert sq % chunk == 0, f"seq {sq} % attn chunk {chunk}"
    qc = q.reshape(b, n, chunk, h, dh).transpose(1, 0, 2, 3, 4)  # (n, B, C, H, Dh)

    def body(_, i):
        q_pos = i * chunk + jnp.arange(chunk) + q_offset
        o = _attn_chunk_masked(
            qc[i], k, v, q_pos, kv_pos, causal=causal, window=window, scale=scale, kv_len=kv_len
        )
        return None, o

    _, outs = jax.lax.scan(body, None, jnp.arange(n))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, v.shape[-1])


def repeat_kv(k: jax.Array, h: int) -> jax.Array:
    """(B, S, KVH, D) -> (B, S, H, D).  Materializing the repeat (instead of a
    grouped einsum) lets the TP axis shard the full `heads` dim — sharding the
    raw kv_heads dim (often 8) on a 16-way model axis would pad 2x."""
    kvh = k.shape[2]
    if kvh == h:
        return k
    return jnp.repeat(k, h // kvh, axis=2)


def _attn_chunk_masked(q, k, v, q_pos, kv_pos, *, causal, window, scale, kv_len):
    b, c, h, dh = q.shape
    k = repeat_kv(k, h)
    v = repeat_kv(v, h)
    # f32 ACCUMULATION via preferred_element_type — never materialize an f32
    # copy of K/V (2x HBM + 2x wire for the sharded decode cache; §Perf A1)
    scores = jnp.einsum(
        "bchd,bshd->bchs", q, k, preferred_element_type=jnp.float32
    )
    scores *= scale
    mask = jnp.ones((c, kv_pos.shape[0]), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= kv_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        mask &= (kv_pos < kv_len)[None, :]
    scores = jnp.where(mask[None, :, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bchs,bshd->bchd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# sharding annotation helper — logical constraint applied inside jit bodies.
# Resolution to mesh axes happens through runtime.sharding rules; when no
# mesh/rules are active this is the identity (single-device smoke tests).
# --------------------------------------------------------------------------

_LOGICAL_RULES: Dict[str, Any] = {}
_MESH = None


def set_logical_rules(mesh, rules: Dict[str, Any]) -> None:
    global _MESH, _LOGICAL_RULES
    _MESH = mesh
    _LOGICAL_RULES = dict(rules)


def clear_logical_rules() -> None:
    global _MESH, _LOGICAL_RULES
    _MESH = None
    _LOGICAL_RULES = {}


def logical_constraint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the active logical rules (no-op if none)."""
    if _MESH is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = tuple(_LOGICAL_RULES.get(a) if a else None for a in axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*spec)))
