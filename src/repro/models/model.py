"""Unified model API over all assigned families.

    model_specs(cfg)            -> ParamSpec pytree (single source of truth)
    loss_fn(cfg, params, batch) -> (loss, metrics)      [train]
    prefill(cfg, params, batch) -> (last_logits, cache) [inference-prefill]
    decode_step(cfg, params, cache, token, pos)         [inference-decode]
    cache_specs(cfg, batch, seq_len)
    input_specs(cfg, cell)      -> ShapeDtypeStruct stand-ins for the dry-run

The cross-entropy is computed in sequence chunks against the (possibly
vocab-sharded) head so full (B, S, V) logits are never materialized.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import encdec, hybrid, mamba, nn, transformer
from repro.models.nn import ParamSpec, logical_constraint

LOSS_CHUNK = 256
COMPUTE_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------------
# specs
# --------------------------------------------------------------------------


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.family in ("dense", "vlm", "moe"):
        return transformer.lm_specs(cfg)
    if cfg.family == "ssm":
        s: Dict[str, Any] = {
            "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
            "blocks": nn.stack_specs(mamba.mamba1_specs(cfg), cfg.num_layers),
            "ln_f": ParamSpec((cfg.d_model,), (None,), "ones"),
        }
        if not cfg.tie_embeddings:
            s["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
        return s
    if cfg.family == "hybrid":
        s = {
            "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
            "trunk": hybrid.trunk_specs(cfg),
            "ln_f": ParamSpec((cfg.d_model,), (None,), "ones"),
        }
        if not cfg.tie_embeddings:
            s["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
        return s
    if cfg.family == "audio":
        return encdec.model_specs(cfg)
    raise ValueError(cfg.family)


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    total = nn.param_count(model_specs(cfg))
    if active_only and cfg.family == "moe":
        moe_layers = cfg.num_layers - cfg.first_dense_layers
        routed = moe_layers * cfg.num_experts * 3 * cfg.d_model * cfg.moe_d_ff
        active = moe_layers * cfg.top_k * 3 * cfg.d_model * cfg.moe_d_ff
        total = total - routed + active
    return total


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------


def _embed(cfg: ModelConfig, params, tokens: jax.Array) -> jax.Array:
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    return logical_constraint(x, "act_batch", None, None)


def _head_weight(cfg: ModelConfig, params) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T  # (d, V)
    return params["lm_head"]


def logits_at(cfg: ModelConfig, params, hidden: jax.Array) -> jax.Array:
    """hidden: (..., d) -> f32 logits (..., V)."""
    w = _head_weight(cfg, params).astype(COMPUTE_DTYPE)
    out = jnp.einsum("...d,dv->...v", hidden, w).astype(jnp.float32)
    return out


# --------------------------------------------------------------------------
# trunk forward per family (training / teacher-forced)
# --------------------------------------------------------------------------


def forward_hidden(
    cfg: ModelConfig, params, batch: Dict[str, jax.Array], *, training: bool,
    make_cache: bool = False,
):
    """Returns (hidden_for_loss, cache, aux_loss)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        tokens = batch["tokens"]
        x = _embed(cfg, params, tokens)
        if fam == "vlm":
            patches = batch["patches"].astype(COMPUTE_DTYPE)
            x = jnp.concatenate([patches, x], axis=1)
        positions = jnp.arange(x.shape[1])
        x, cache, aux = transformer.trunk_forward(
            cfg, params, x, positions, training=training, make_cache=make_cache
        )
        x = nn.rms_norm(x, params["ln_f"], cfg.norm_eps)
        if fam == "vlm":
            x = x[:, batch["patches"].shape[1] :]  # loss over text positions only
        return x, cache, aux

    if fam == "ssm":
        x = _embed(cfg, params, batch["tokens"])

        def body(xx, p_l):
            xx, c = mamba.mamba1_forward(cfg, p_l, xx, make_cache=make_cache)
            return xx, c

        if training and cfg.remat != "nothing":
            body = (
                jax.checkpoint(body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
                if cfg.remat == "dots"
                else jax.checkpoint(body)
            )
        x, cache = jax.lax.scan(body, x, params["blocks"])
        x = nn.rms_norm(x, params["ln_f"], cfg.norm_eps)
        return x, cache, jnp.zeros((), jnp.float32)

    if fam == "hybrid":
        emb = _embed(cfg, params, batch["tokens"])
        positions = jnp.arange(emb.shape[1])
        x, cache = hybrid.trunk_forward(
            cfg, params["trunk"], emb, emb, positions, training=training, make_cache=make_cache
        )
        x = nn.rms_norm(x, params["ln_f"], cfg.norm_eps)
        return x, cache, jnp.zeros((), jnp.float32)

    if fam == "audio":
        frames = batch["frames"].astype(COMPUTE_DTYPE)
        enc_out = encdec.encode(cfg, params, frames, training=training)
        x, cache = encdec.decode_train(
            cfg, params, batch["tokens"], enc_out, training=training, make_cache=make_cache
        )
        return x, cache, jnp.zeros((), jnp.float32)

    raise ValueError(fam)


# --------------------------------------------------------------------------
# chunked cross-entropy loss
# --------------------------------------------------------------------------


def loss_fn(
    cfg: ModelConfig, params, batch: Dict[str, jax.Array], *, training: bool = True,
    aux_weight: float = 0.01, z_weight: float = 1e-4,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    hidden, _, aux = forward_hidden(cfg, params, batch, training=training)
    labels = batch["labels"]
    w = _head_weight(cfg, params).astype(COMPUTE_DTYPE)

    b, s, d = hidden.shape
    chunk = min(LOSS_CHUNK, s)
    if s % chunk:
        chunk = s
    n = s // chunk
    hc = hidden.reshape(b, n, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, n, chunk).swapaxes(0, 1)

    def body(acc, inp):
        h_c, l_c = inp
        logits = jnp.einsum("bsd,dv->bsv", h_c, w).astype(jnp.float32)
        logits = logical_constraint(logits, "act_batch", None, "vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(l_c, 0)[..., None], axis=-1)[..., 0]
        mask = (l_c >= 0).astype(jnp.float32)
        nll = (logz - ll) * mask
        zed = jnp.square(logz) * mask
        nll_sum, z_sum, cnt = acc
        return (nll_sum + nll.sum(), z_sum + zed.sum(), cnt + mask.sum()), None

    (nll_sum, z_sum, cnt), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), (hc, lc)
    )
    cnt = jnp.maximum(cnt, 1.0)
    ce = nll_sum / cnt
    loss = ce + z_weight * z_sum / cnt + aux_weight * aux
    metrics = {"loss": loss, "ce": ce, "aux": aux, "tokens": cnt}
    return loss, metrics


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    hidden, cache, _ = forward_hidden(cfg, params, batch, training=False, make_cache=True)
    last = hidden[:, -1, :]
    return logits_at(cfg, params, last), cache


def decode_step(cfg: ModelConfig, params, cache, token: jax.Array, pos: jax.Array):
    """token: (B,) int32, pos: scalar int32 (write position). -> (logits, cache)."""
    fam = cfg.family
    x = params["embed"].astype(COMPUTE_DTYPE)[token][:, None, :]
    if fam in ("dense", "moe", "vlm"):
        x, cache = transformer.trunk_decode(cfg, params, x, cache, pos)
    elif fam == "ssm":

        def body(xx, scanned):
            p_l, c_l = scanned
            xx, c = mamba.mamba1_decode(cfg, p_l, xx, c_l)
            return xx, c

        x, cache = jax.lax.scan(body, x, (params["blocks"], cache))
    elif fam == "hybrid":
        emb = x
        x, cache = hybrid.trunk_decode(cfg, params["trunk"], x, emb, cache, pos)
    elif fam == "audio":
        x, cache = encdec.decode_step(cfg, params, cache, token, pos)
        return logits_at(cfg, params, x[:, 0]), cache
    else:
        raise ValueError(fam)
    x = nn.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return logits_at(cfg, params, x[:, 0]), cache


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> Any:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return transformer.cache_specs(cfg, batch, seq_len)
    if fam == "ssm":
        return nn.stack_specs(mamba.mamba1_cache_specs(cfg, batch), cfg.num_layers)
    if fam == "hybrid":
        return hybrid.cache_specs(cfg, batch, seq_len)
    if fam == "audio":
        return encdec.cache_specs(cfg, batch, seq_len)
    raise ValueError(fam)


# --------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """Abstract inputs for one (arch x shape) cell."""
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        out = {}
        if cfg.family == "vlm":
            p = cfg.num_prefix_tokens
            out["tokens"] = _sds((b, s - p), jnp.int32)
            out["patches"] = _sds((b, p, cfg.d_model), COMPUTE_DTYPE)
            out["labels"] = _sds((b, s - p), jnp.int32)
        elif cfg.family == "audio":
            out["frames"] = _sds((b, s, cfg.d_model), COMPUTE_DTYPE)
            out["tokens"] = _sds((b, s), jnp.int32)
            out["labels"] = _sds((b, s), jnp.int32)
        else:
            out["tokens"] = _sds((b, s), jnp.int32)
            out["labels"] = _sds((b, s), jnp.int32)
        return out

    if cell.kind == "prefill":
        out = {}
        if cfg.family == "vlm":
            p = cfg.num_prefix_tokens
            out["tokens"] = _sds((b, s - p), jnp.int32)
            out["patches"] = _sds((b, p, cfg.d_model), COMPUTE_DTYPE)
        elif cfg.family == "audio":
            out["frames"] = _sds((b, s, cfg.d_model), COMPUTE_DTYPE)
            out["tokens"] = _sds((b, s), jnp.int32)
        else:
            out["tokens"] = _sds((b, s), jnp.int32)
        return out

    if cell.kind == "decode":
        cache = jax.tree.map(
            lambda sp: _sds(sp.shape, COMPUTE_DTYPE if sp.shape else COMPUTE_DTYPE),
            cache_specs(cfg, b, s),
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        # SSM states stay f32 (accumulated recurrence)
        if cfg.family == "ssm":
            cache = {
                "state": _sds(cache["state"].shape, jnp.float32),
                "conv": cache["conv"],
            }
        elif cfg.family == "hybrid":
            cache = dict(cache)
            for k in list(cache):
                if k.startswith("ssm"):
                    cache[k] = {
                        "state": _sds(cache[k]["state"].shape, jnp.float32),
                        "conv": cache[k]["conv"],
                    }
        return {
            "token": _sds((b,), jnp.int32),
            "pos": _sds((), jnp.int32),
            "cache": cache,
        }

    raise ValueError(cell.kind)
