"""Selective state-space blocks: Mamba1 (falcon-mamba-7b) and Mamba2
(zamba2-1.2b backbone).

The sequence dimension is processed with a *chunked* selective scan: the
discretized transition/input terms (da, dbx) — the big (B, c, d_inner,
d_state) tensors — are materialized only per chunk inside the ``lax.scan``
body, the within-chunk recurrence h_t = a_t * h_{t-1} + b_t runs as an
associative scan, and chunks carry the boundary state sequentially.  Peak
memory is O(B * chunk * d_inner * d_state) instead of O(B * S * ...) — the
same tiling contract the Pallas ``mamba_scan`` kernel implements in VMEM on
TPU (kernels/mamba_scan validates against this path).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import nn
from repro.models.nn import ParamSpec, logical_constraint

SCAN_CHUNK = 256


# --------------------------------------------------------------------------
# chunk-scan skeleton
# --------------------------------------------------------------------------


def _assoc_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def run_chunked_scan(
    seq_inputs: Any,  # pytree of (B, S, ...) arrays
    h0: jax.Array,
    chunk: int,
    body_fn: Callable,  # (h_in, chunk_inputs) -> (h_out, y_chunk (B, c, ...))
):
    s = jax.tree.leaves(seq_inputs)[0].shape[1]
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s  # irregular smoke-test lengths: single chunk
    n = s // chunk

    def split(x):  # (B, S, ...) -> (n, B, c, ...)
        return x.reshape(x.shape[0], n, chunk, *x.shape[2:]).swapaxes(0, 1)

    chunks = jax.tree.map(split, seq_inputs)
    h_last, y_chunks = jax.lax.scan(body_fn, h0, chunks)
    y = y_chunks.swapaxes(0, 1)
    return y.reshape(y.shape[0], s, *y.shape[3:]), h_last


def intra_chunk_scan(da: jax.Array, dbx: jax.Array, h_in: jax.Array):
    """da, dbx: (B, c, ...state); h_in: (B, ...state) -> (h_all, h_last)."""
    a_cum, b_cum = jax.lax.associative_scan(_assoc_combine, (da, dbx), axis=1)
    h_all = b_cum + a_cum * h_in[:, None]
    return h_all, h_all[:, -1]


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via k shifted adds. x: (B, S, C), w: (C, k)."""
    k = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    s = x.shape[1]
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + xp[:, j : j + s, :] * w[:, j].astype(x.dtype)
    return out + b.astype(x.dtype)


def causal_conv_step(x_t: jax.Array, tail: jax.Array, w: jax.Array, b: jax.Array):
    """One-token conv. x_t: (B, C); tail: (B, k-1, C) previous raw inputs."""
    window = jnp.concatenate([tail, x_t[:, None, :]], axis=1)  # (B, k, C)
    out = jnp.einsum("bkc,ck->bc", window, w.astype(x_t.dtype)) + b.astype(x_t.dtype)
    return out, window[:, 1:, :]


def _conv_tail(x_raw: jax.Array, k: int) -> jax.Array:
    s = x_raw.shape[1]
    if s >= k - 1:
        return x_raw[:, -(k - 1) :, :]
    return jnp.pad(x_raw, ((0, 0), (k - 1 - s, 0), (0, 0)))


# --------------------------------------------------------------------------
# Mamba1 (falcon-mamba)
# --------------------------------------------------------------------------


def mamba1_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, di, n, k, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv, cfg.dt_rank
    return {
        "ln": ParamSpec((d,), (None,), "ones"),
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((di, k), ("ssm_inner", None)),
        "conv_b": ParamSpec((di,), ("ssm_inner",), "zeros"),
        "x_proj": ParamSpec((di, r + 2 * n), ("ssm_inner", None)),
        "dt_w": ParamSpec((r, di), (None, "ssm_inner")),
        "dt_b": ParamSpec((di,), ("ssm_inner",), "dt_bias"),
        "A_log": ParamSpec((di, n), ("ssm_inner", None), "s4d"),
        "D": ParamSpec((di,), ("ssm_inner",), "ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed")),
    }


def _mamba1_gates(cfg: ModelConfig, p, xi: jax.Array):
    """xi: (B, ..., di) post-conv activations -> dt, B, C (f32)."""
    n, r = cfg.ssm_state, cfg.dt_rank
    proj = jnp.einsum("...c,cr->...r", xi, p["x_proj"].astype(xi.dtype))
    dt_low, bb, cc = jnp.split(proj, [r, r + n], axis=-1)
    dt = jnp.einsum("...r,rc->...c", dt_low, p["dt_w"].astype(xi.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_b"].astype(jnp.float32))
    return dt, bb.astype(jnp.float32), cc.astype(jnp.float32)


def mamba1_forward(cfg: ModelConfig, p, x: jax.Array, *, make_cache: bool = False):
    """x: (B, S, d) -> (y, cache | None)."""
    bsz, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    h = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(h.dtype))
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = logical_constraint(xi, "act_batch", None, "ssm_inner")
    xc = nn.silu(causal_conv(xi, p["conv_w"], p["conv_b"]))

    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, n)
    d_skip = p["D"].astype(jnp.float32)

    def body(h_in, xc_c):
        dt, bb, cc = _mamba1_gates(cfg, p, xc_c)  # (B, c, di|n)
        da = jnp.exp(dt[..., None] * A)  # (B, c, di, n)
        dbx = (dt * xc_c.astype(jnp.float32))[..., None] * bb[:, :, None, :]
        h_all, h_out = intra_chunk_scan(da, dbx, h_in)
        y = jnp.einsum("bscn,bsn->bsc", h_all, cc)
        y = y + d_skip * xc_c.astype(jnp.float32)
        return h_out, y.astype(x.dtype)

    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    y, h_last = run_chunked_scan(xc, h0, SCAN_CHUNK, body)
    y = (y.astype(jnp.float32) * nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"].astype(x.dtype))

    cache = None
    if make_cache:
        cache = {"state": h_last, "conv": _conv_tail(xi, cfg.ssm_conv)}
    return x + out, cache


def mamba1_decode(cfg: ModelConfig, p, x: jax.Array, cache):
    """x: (B, 1, d); cache {state: (B, di, n), conv: (B, k-1, di)}."""
    h = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(h.dtype))
    xi, z = jnp.split(xz[:, 0], 2, axis=-1)  # (B, di)
    xc, new_tail = causal_conv_step(xi, cache["conv"], p["conv_w"], p["conv_b"])
    xc = nn.silu(xc)
    dt, bb, cc = _mamba1_gates(cfg, p, xc)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt[..., None] * A)  # (B, di, n)
    dbx = (dt * xc.astype(jnp.float32))[..., None] * bb[:, None, :]
    hst = da * cache["state"] + dbx
    y = jnp.einsum("bcn,bn->bc", hst, cc) + p["D"].astype(jnp.float32) * xc.astype(
        jnp.float32
    )
    y = (y * nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bc,cd->bd", y, p["out_proj"].astype(x.dtype))[:, None]
    return x + out, {"state": hst, "conv": new_tail}


def mamba1_cache_specs(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    return {
        "state": ParamSpec((batch, cfg.d_inner, cfg.ssm_state), ("act_batch", "ssm_inner", None)),
        "conv": ParamSpec((batch, cfg.ssm_conv - 1, cfg.d_inner), ("act_batch", None, "ssm_inner")),
    }


# --------------------------------------------------------------------------
# Mamba2 (zamba2 backbone)
# --------------------------------------------------------------------------


def mamba2_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, di, n, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    nh = cfg.ssm_heads
    conv_dim = di + 2 * n
    return {
        "ln": ParamSpec((d,), (None,), "ones"),
        "in_proj": ParamSpec((d, 2 * di + 2 * n + nh), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((conv_dim, k), ("ssm_inner", None)),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), "zeros"),
        "A_log": ParamSpec((nh,), ("ssm_heads",), "s4d"),
        "D": ParamSpec((nh,), ("ssm_heads",), "ones"),
        "dt_b": ParamSpec((nh,), ("ssm_heads",), "dt_bias"),
        "norm": ParamSpec((di,), ("ssm_inner",), "ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed")),
    }


def mamba2_forward(cfg: ModelConfig, p, x: jax.Array, *, make_cache: bool = False):
    bsz, s, _ = x.shape
    di, n, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(h.dtype))
    z, xbc_raw, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    xbc = nn.silu(causal_conv(xbc_raw, p["conv_w"], p["conv_b"]))
    xi, bb, cc = jnp.split(xbc, [di, di + n], axis=-1)
    xi = logical_constraint(xi, "act_batch", None, "ssm_inner")

    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    d_skip = p["D"].astype(jnp.float32)

    def body_scan(h_in, inputs):
        """Elementwise associative scan: materializes (B, c, H, P, N) state
        tensors per chunk — HBM-bound on the XLA path (§Perf B baseline)."""
        xi_c, bb_c, cc_c, dtr_c = inputs  # (B, c, ...)
        dt = jax.nn.softplus(dtr_c.astype(jnp.float32) + p["dt_b"].astype(jnp.float32))
        da = jnp.exp(dt * A)  # (B, c, H)
        xh = xi_c.reshape(*xi_c.shape[:2], nh, hp).astype(jnp.float32)
        dbx = (dt[..., None] * xh)[..., None] * bb_c.astype(jnp.float32)[:, :, None, None, :]
        da_b = jnp.broadcast_to(da[..., None, None], dbx.shape)
        h_all, h_out = intra_chunk_scan(da_b, dbx, h_in)
        y = jnp.einsum("bshpn,bsn->bshp", h_all, cc_c.astype(jnp.float32))
        y = y + d_skip[:, None] * xh
        return h_out, y.reshape(*xi_c.shape[:2], di).astype(x.dtype)

    def body_ssd(h_in, inputs):
        """SSD (matmul) form of the same recurrence [Mamba2 paper §6]: the
        per-chunk working set is (B, c, c, H) attention-like matrices instead
        of (B, c, H, P, N) states — ~N x less HBM traffic, and the work runs
        as MXU matmuls (§Perf B optimized)."""
        xi_c, bb_c, cc_c, dtr_c = inputs
        c = xi_c.shape[1]
        dt = jax.nn.softplus(dtr_c.astype(jnp.float32) + p["dt_b"].astype(jnp.float32))
        da = dt * A  # (B, c, H), negative
        cs = jnp.cumsum(da, axis=1)  # inclusive log-decay prefix
        xh = xi_c.reshape(bsz, c, nh, hp).astype(jnp.float32)
        bbf = bb_c.astype(jnp.float32)
        ccf = cc_c.astype(jnp.float32)
        # intra-chunk: y_i += sum_{j<=i} exp(cs_i - cs_j) dt_j (C_i.B_j) x_j
        diff = cs[:, :, None, :] - cs[:, None, :, :]  # (B, c, c, H), <= 0 on tril
        tril = jnp.tril(jnp.ones((c, c), bool))
        L = jnp.where(tril[None, :, :, None], jnp.exp(diff), 0.0)
        L = L * dt[:, None, :, :]  # decay * dt_j
        G = jnp.einsum("bin,bjn->bij", ccf, bbf)  # (B, c, c) C_i . B_j
        M = G[..., None] * L  # (B, c, c, H)
        y = jnp.einsum("bijh,bjhp->bihp", M, xh)
        # inter-chunk: y_i += exp(cs_i) C_i . h_in
        y = y + jnp.exp(cs)[..., None] * jnp.einsum("bin,bhpn->bihp", ccf, h_in)
        y = y + d_skip[:, None] * xh
        # carry: h_out = exp(cs_last) h_in + sum_j exp(cs_last - cs_j) b_j
        decay_end = jnp.exp(cs[:, -1:, :] - cs) * dt  # (B, c, H)
        h_out = jnp.exp(cs[:, -1, :])[..., None, None] * h_in + jnp.einsum(
            "bch,bchp,bcn->bhpn", decay_end, xh, bbf
        )
        return h_out, y.reshape(bsz, c, di).astype(x.dtype)

    body = body_ssd if cfg.ssm_algo == "ssd" else body_scan
    chunk = SCAN_CHUNK if cfg.ssm_algo == "ssd" else SCAN_CHUNK // 4
    h0 = jnp.zeros((bsz, nh, hp, n), jnp.float32)
    y, h_last = run_chunked_scan((xi, bb, cc, dt_raw), h0, chunk, body)
    y = nn.rms_norm(
        (y.astype(jnp.float32) * nn.silu(z.astype(jnp.float32))).astype(x.dtype),
        p["norm"],
        cfg.norm_eps,
    )
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"].astype(x.dtype))

    cache = None
    if make_cache:
        cache = {"state": h_last, "conv": _conv_tail(xbc_raw, cfg.ssm_conv)}
    return x + out, cache


def mamba2_decode(cfg: ModelConfig, p, x: jax.Array, cache):
    bsz = x.shape[0]
    di, n, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(h.dtype))[:, 0]
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    xbc_c, new_tail = causal_conv_step(xbc, cache["conv"], p["conv_w"], p["conv_b"])
    xbc_c = nn.silu(xbc_c)
    xi, bb, cc = jnp.split(xbc_c, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_b"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * A)  # (B,H)
    xh = xi.reshape(bsz, nh, hp).astype(jnp.float32)
    dbx = (dt[..., None] * xh)[..., None] * bb.astype(jnp.float32)[:, None, None, :]
    hst = da[..., None, None] * cache["state"] + dbx
    y = jnp.einsum("bhpn,bn->bhp", hst, cc.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(bsz, di)
    y = nn.rms_norm(
        (y * nn.silu(z.astype(jnp.float32))).astype(x.dtype), p["norm"], cfg.norm_eps
    )
    out = jnp.einsum("bc,cd->bd", y, p["out_proj"].astype(x.dtype))[:, None]
    return x + out, {"state": hst, "conv": new_tail}


def mamba2_cache_specs(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "state": ParamSpec(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            ("act_batch", "ssm_heads", None, None),
        ),
        "conv": ParamSpec((batch, cfg.ssm_conv - 1, conv_dim), ("act_batch", None, "ssm_inner")),
    }
