"""Whisper-style encoder-decoder backbone.  The conv/mel frontend is a STUB
per the assignment: ``input_specs()`` feeds precomputed frame embeddings
(B, S, d_model) straight into the encoder.  Sinusoidal positions, MHA,
pre-norm blocks; decoder has causal self-attention (cached at decode) and
cross-attention over encoder states (K/V cached once at prefill).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import nn, transformer
from repro.models.nn import ParamSpec


def cross_attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d, h * dh), ("embed", "heads")),
        "wk": ParamSpec((d, h * dh), ("embed", "heads")),
        "wv": ParamSpec((d, h * dh), ("embed", "heads")),
        "wo": ParamSpec((h * dh, d), ("heads", "embed")),
    }


def enc_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return transformer.block_specs(cfg, is_moe=False)


def dec_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    s = transformer.block_specs(cfg, is_moe=False)
    s["lnx"] = ParamSpec((cfg.d_model,), (None,), "ones")
    s["cross"] = cross_attn_specs(cfg)
    return s


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
        "enc": nn.stack_specs(enc_block_specs(cfg), cfg.enc_layers),
        "dec": nn.stack_specs(dec_block_specs(cfg), cfg.dec_layers),
        "ln_enc": ParamSpec((cfg.d_model,), (None,), "ones"),
        "ln_f": ParamSpec((cfg.d_model,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return s


def _cross_kv(cfg: ModelConfig, p, enc_out: jax.Array):
    b, s, _ = enc_out.shape
    h, dh = cfg.num_heads, cfg.head_dim
    k = jnp.einsum("bsd,dk->bsk", enc_out, p["wk"].astype(enc_out.dtype)).reshape(b, s, h, dh)
    v = jnp.einsum("bsd,dk->bsk", enc_out, p["wv"].astype(enc_out.dtype)).reshape(b, s, h, dh)
    return k, v


def _cross_attn(cfg: ModelConfig, p, x: jax.Array, k: jax.Array, v: jax.Array):
    b, s, _ = x.shape
    h, dh = cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"].astype(x.dtype)).reshape(b, s, h, dh)
    o = nn.attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return jnp.einsum("bsk,kd->bsd", o.reshape(b, s, -1), p["wo"].astype(x.dtype))


def encode(cfg: ModelConfig, params, frames: jax.Array, *, training: bool) -> jax.Array:
    x = frames + nn.sinusoidal_pos(frames.shape[1], cfg.d_model).astype(frames.dtype)
    positions = jnp.arange(frames.shape[1])

    def body(xx, p_l):
        xx, _, _ = transformer.apply_block(
            cfg, p_l, xx, positions, is_moe=False, causal=False
        )
        return xx, None

    if training and cfg.remat != "nothing":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return nn.rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _dec_block(cfg, p_l, x, enc_out, positions, *, make_cache):
    h = nn.rms_norm(x, p_l["ln1"], cfg.norm_eps)
    a, self_cache = transformer.gqa_attn_forward(
        cfg, p_l["attn"], h, positions, make_cache=make_cache, causal=True
    )
    x = x + a
    h = nn.rms_norm(x, p_l["lnx"], cfg.norm_eps)
    ck, cv = _cross_kv(cfg, p_l["cross"], enc_out)
    x = x + _cross_attn(cfg, p_l["cross"], h, ck, cv)
    h = nn.rms_norm(x, p_l["ln2"], cfg.norm_eps)
    x = x + nn.swiglu(h, p_l["ffn"]["w_gate"], p_l["ffn"]["w_up"], p_l["ffn"]["w_down"])
    cache = None
    if make_cache:
        cache = {"k": self_cache["k"], "v": self_cache["v"], "ck": ck, "cv": cv}
    return x, cache


def decode_train(cfg: ModelConfig, params, tokens: jax.Array, enc_out: jax.Array,
                 *, training: bool, make_cache: bool = False):
    x = params["embed"].astype(enc_out.dtype)[tokens]
    x = x + nn.sinusoidal_pos(tokens.shape[1], cfg.d_model).astype(x.dtype)
    positions = jnp.arange(tokens.shape[1])

    def body(xx, p_l):
        xx, cache = _dec_block(cfg, p_l, xx, enc_out, positions, make_cache=make_cache)
        return xx, cache

    if training and cfg.remat != "nothing":
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, params["dec"])
    return nn.rms_norm(x, params["ln_f"], cfg.norm_eps), caches


def decode_step(cfg: ModelConfig, params, caches, token: jax.Array, pos: jax.Array):
    """token: (B,) int32; caches from prefill (self K/V ring + cross K/V)."""
    x = params["embed"][token][:, None, :].astype(jnp.bfloat16)
    x = x + nn.sinusoidal_pos(1, cfg.d_model, offset=pos).astype(x.dtype)

    def body(xx, scanned):
        p_l, c_l = scanned
        h = nn.rms_norm(xx, p_l["ln1"], cfg.norm_eps)
        a, kv = transformer.gqa_attn_decode(
            cfg, p_l["attn"], h, {"k": c_l["k"], "v": c_l["v"]}, pos
        )
        xx = xx + a
        h = nn.rms_norm(xx, p_l["lnx"], cfg.norm_eps)
        xx = xx + _cross_attn(cfg, p_l["cross"], h, c_l["ck"], c_l["cv"])
        h = nn.rms_norm(xx, p_l["ln2"], cfg.norm_eps)
        xx = xx + nn.swiglu(h, p_l["ffn"]["w_gate"], p_l["ffn"]["w_up"], p_l["ffn"]["w_down"])
        return xx, {"k": kv["k"], "v": kv["v"], "ck": c_l["ck"], "cv": c_l["cv"]}

    x, new_caches = jax.lax.scan(body, x, (params["dec"], caches))
    return nn.rms_norm(x, params["ln_f"], cfg.norm_eps), new_caches


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    l, h, dh = cfg.dec_layers, cfg.num_heads, cfg.head_dim
    self_shape = (l, batch, seq_len, cfg.num_kv_heads, dh)  # self-attn stores kv heads
    cross_shape = (l, batch, seq_len, h, dh)  # cross K/V use full heads (MHA proj)
    axes = ("layers", "act_batch", "kv_seq", None, "kv_dh")
    return {
        "k": ParamSpec(self_shape, axes),
        "v": ParamSpec(self_shape, axes),
        "ck": ParamSpec(cross_shape, axes),
        "cv": ParamSpec(cross_shape, axes),
    }
