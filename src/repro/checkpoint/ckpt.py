"""Sharded, compressed, async checkpointing with atomic publish and elastic
(mesh-agnostic) restore.

Layout (one directory per step):

    <dir>/step_000100/
        manifest.json        — step, arrays {path -> shape, dtype, hash},
                               mesh/topology note, data-pipeline state
        arrays/<name>.npz.zst — zstandard-compressed npz, one file per
                               host-rank-owned group (single-host here: one)

Atomicity: written to ``step_X.tmp`` then os.rename'd — a crashed writer
never corrupts the latest checkpoint.  ``save_async`` runs serialization on
a background thread off the training critical path (the arrays are first
snapshot to host to decouple from donated device buffers).  Restore is
mesh-agnostic: values are re-device_put with the CURRENT sharding rules, so
restoring onto a different DP/TP degree (elastic scaling) just works.
zstd on fp32 optimizer state is the checkpoint-path cousin of DaeMon's link
compression (page-granularity movement compressed off the hot path).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: fail at first (de)compress, not import
    zstandard = None

_FLAT_SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _FLAT_SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(tree: Any, flat: Dict[str, np.ndarray]) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    vals = []
    for path, leaf in leaves:
        key = _FLAT_SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        v = flat[key]
        expect = getattr(leaf, "shape", None)
        if expect is not None and tuple(v.shape) != tuple(expect):
            raise ValueError(f"{key}: checkpoint shape {v.shape} != expected {expect}")
        vals.append(v)
    return jax.tree_util.tree_unflatten(treedef, vals)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---------------- save ----------------
    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: Dict) -> Path:
        if zstandard is None:  # before any filesystem mutation
            raise ImportError("checkpoint save requires the 'zstandard' package")
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        (tmp / "arrays").mkdir(parents=True)
        cctx = zstandard.ZstdCompressor(level=3)
        manifest = {"step": step, "arrays": {}, "extra": extra, "time": time.time()}
        buf = io.BytesIO()
        np.savez(buf, **flat)
        payload = cctx.compress(buf.getvalue())
        (tmp / "arrays" / "shard_0.npz.zst").write_bytes(payload)
        for k, v in flat.items():
            manifest["arrays"][k] = {
                "shape": list(v.shape),
                "dtype": str(v.dtype),
            }
        manifest["hash"] = hashlib.sha256(payload).hexdigest()
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> Path:
        return self._write(step, _flatten(tree), extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        """Snapshot to host now; compress+write on a background thread."""
        self.wait()
        flat = _flatten(tree)  # host copy (decouples from donated buffers)

        def work():
            try:
                self._write(step, flat, extra or {})
            except BaseException as e:  # surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ---------------- restore ----------------
    def all_steps(self) -> List[int]:
        return sorted(
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if p.is_dir() and not p.name.endswith(".tmp")
        )

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, step: Optional[int], like: Any, *, shardings: Any = None,
        validate_hash: bool = True,
    ) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like``; device_put with
        ``shardings`` (tree or prefix) if given — elastic re-shard happens
        here: the stored global arrays are laid out for whatever mesh the
        caller is running now."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        payload = (d / "arrays" / "shard_0.npz.zst").read_bytes()
        if validate_hash:
            h = hashlib.sha256(payload).hexdigest()
            if h != manifest["hash"]:
                raise IOError(f"checkpoint {d} corrupt: hash mismatch")
        if zstandard is None:
            raise ImportError("checkpoint load requires the 'zstandard' package")
        dctx = zstandard.ZstdDecompressor()
        with np.load(io.BytesIO(dctx.decompress(payload))) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(like, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda v, s: jax.device_put(v, s), tree, shardings
            )
        return tree, manifest["extra"]
