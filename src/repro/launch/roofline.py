"""Roofline-term extraction from compiled dry-run artifacts.

Three terms, all in *seconds per step, per chip* (TPU v5e constants):

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = collective_operand_bytes_per_device / ICI_BW

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are NOT
in cost_analysis, so we parse the post-SPMD HLO (``compiled.as_text()``),
build a symbol table of every op's result shape, and sum the operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops (shapes in the partitioned module are per-device).  We also report a
ring-wire estimate (all-reduce counts 2x) for context.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# ---- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)

# result definition:  %name = TYPE[dims]{layout} opcode(
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?)([a-z0-9]+)\[([\d,]*)\]"
)
_TUPLE_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPCODE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*([\w\-]+)(?:\.\d+)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_result_bytes(line: str) -> Optional[int]:
    """Total bytes of the result (handles tuple-shaped results)."""
    m = _DEF_RE.match(line)
    if not m:
        return None
    if m.group(2) == "(":  # tuple result: sum all component shapes up to ') '
        close = line.find(") ", m.start())
        seg = line[m.start() : close if close != -1 else len(line)]
        return sum(_shape_bytes(d, s) for d, s in _TUPLE_SHAPE_RE.findall(seg))
    return _shape_bytes(m.group(3), m.group(4))


@dataclass
class CollectiveStats:
    operand_bytes: Dict[str, int] = field(default_factory=dict)  # kind -> bytes
    result_bytes: Dict[str, int] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    def wire_bytes(self) -> int:
        """Ring estimate: all-reduce moves ~2x its operand; others ~1x."""
        total = 0
        for kind, b in self.operand_bytes.items():
            total += 2 * b if kind == "all-reduce" else b
        return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    # 1. symbol table: op name -> result bytes
    table: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            b = _line_result_bytes(line)
            if b is not None:
                table[m.group(1)] = b

    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-start(" in line or "-done(" in line:
            # async pairs: count only the -start (has the operands)
            if "-done(" in line:
                continue
        kind = None
        for k in COLLECTIVE_KINDS:
            if f" {k}(" in line or f" {k}-start(" in line:
                kind = k
                break
        if kind is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        # operand list: %refs inside the call parens
        call = line.split("(", 1)[1] if "(" in line else ""
        refs = re.findall(r"%([\w.\-]+)", call)
        ob = sum(table.get(r, 0) for r in refs)
        if ob == 0:  # fallback: use result bytes
            ob = _line_result_bytes(line) or 0
        stats.operand_bytes[kind] = stats.operand_bytes.get(kind, 0) + ob
        stats.result_bytes[kind] = stats.result_bytes.get(kind, 0) + (_line_result_bytes(line) or 0)
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device bytes accessed
    collective_bytes: float  # per-device collective operand bytes
    wire_bytes: float
    n_devices: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "wire_bytes": self.wire_bytes,
            "n_devices": self.n_devices,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def analyze(compiled, n_devices: int) -> Tuple[Roofline, CollectiveStats]:
    """Trip-count-corrected terms (see hlo_cost.py: XLA's cost_analysis counts
    scan bodies once; we re-derive flops/bytes/collectives from the HLO with
    known_trip_count multiplication).  XLA's raw numbers are kept alongside
    for reference."""
    from repro.launch import hlo_cost

    text = compiled.as_text()
    corrected = hlo_cost.analyze_text(text)
    stats = CollectiveStats(
        operand_bytes={k: int(v) for k, v in corrected["collective_bytes"].items()},
        result_bytes={},
        counts=dict(corrected["collective_counts"]),
    )
    rl = Roofline(
        flops=float(corrected["flops"]),
        hbm_bytes=float(corrected["hbm_bytes"]),
        collective_bytes=float(stats.total_operand_bytes),
        wire_bytes=float(stats.wire_bytes()),
        n_devices=n_devices,
    )
    return rl, stats


def xla_raw_cost(compiled) -> Dict[str, float]:
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        return {
            "xla_flops_raw": float(cost.get("flops", 0.0)),
            "xla_bytes_raw": float(cost.get("bytes accessed", 0.0)),
        }
    except Exception:
        return {}


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens/step.
    For decode cells D = global_batch (one token each); attention extra
    ~12*L*d_head*H*S*D is NOT counted (keeps the published convention)."""
    from repro.models import model as M

    n = M.param_count(cfg, active_only=(cfg.family == "moe"))
    if cell.kind == "train":
        d = cell.global_batch * cell.seq_len
        return 6.0 * n * d
    if cell.kind == "prefill":
        d = cell.global_batch * cell.seq_len
        return 2.0 * n * d  # forward only
    d = cell.global_batch  # decode: one token per sequence
    return 2.0 * n * d
