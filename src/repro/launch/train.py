"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
        --steps 50 --batch 8 --seq 128 --movement daemon --ckpt-dir /tmp/ck

Wires together: config -> mesh/shardings -> data pipeline -> (baseline |
daemon) train step -> async checkpointing -> supervisor (heartbeat +
straggler policy) -> elastic restart-from-checkpoint.  On this CPU container
it runs REDUCED configs for real (examples/train_lm.py trains a ~100M model);
full configs go through the dry-run instead.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import movement as mv
from repro.data import DataConfig, TokenPipeline
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.models import nn
from repro.optim import adamw
from repro.runtime import sharding as shd
from repro.runtime.fault import HeartbeatMonitor, RunSupervisor, StragglerPolicy


def train(
    arch: str,
    *,
    reduced: bool = True,
    steps: int = 50,
    global_batch: int = 8,
    seq_len: int = 128,
    movement: str = "baseline",
    peak_lr: float = 3e-4,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 20,
    resume: bool = False,
    mesh_shape=None,
    num_microbatches: int = 1,
    log_every: int = 10,
    seed: int = 0,
):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_mesh(mesh_shape or (1, 1))
    rules = shd.base_rules(mesh, fsdp=True)
    shd.activate(mesh, rules)
    specs = M.model_specs(cfg)
    psh = shd.sharding_for_specs(mesh, rules, specs)

    master = nn.init_params(specs, jax.random.key(seed))
    master = jax.tree.map(lambda p, s: jax.device_put(p, s), master, psh)

    step_fn = steps_lib.make_train_step(
        cfg, peak_lr=peak_lr, total_steps=steps, movement=movement,
        num_microbatches=num_microbatches,
    )
    if movement == "daemon":
        state = mv.init_state(master)
        params = mv.working_copy(master, mv.DAEMON_DEFAULT)
    else:
        state = adamw.init(master)
        params = master

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if mgr and resume and mgr.latest_step() is not None:
        (params, state), extra = mgr.restore(None, (params, state), shardings=None)
        start_step = int(extra.get("step", 0))
        print(f"resumed from step {start_step}")

    pipe = TokenPipeline(
        DataConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=global_batch,
            seed=seed,
        ),
        start_step=start_step,
    )
    supervisor = RunSupervisor(
        hosts=list(range(jax.process_count())),
        monitor=HeartbeatMonitor(interval_s=60),
        policy=StragglerPolicy(),
    )

    jstep = jax.jit(step_fn, donate_argnums=(0, 1))
    losses = []
    t_start = time.time()
    for i, host_batch in zip(range(start_step, steps), pipe):
        batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
        if cfg.family == "vlm":
            p = cfg.num_prefix_tokens
            batch["patches"] = jnp.zeros(
                (batch["tokens"].shape[0], p, cfg.d_model), jnp.bfloat16
            )
        elif cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (batch["tokens"].shape[0], seq_len, cfg.d_model), jnp.bfloat16
            )
        t0 = time.time()
        params, state, metrics = jstep(params, state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        supervisor.monitor.beat(0)
        supervisor.tick({0: time.time() - t0})
        if mgr and (i + 1) % ckpt_every == 0:
            mgr.save_async(i + 1, (params, state), {"step": i + 1, "arch": arch})
        if (i + 1) % log_every == 0 or i == start_step:
            print(
                f"step {i+1:5d} loss {loss:.4f} lr {float(metrics['lr']):.2e} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"({(time.time()-t_start)/(i-start_step+1):.2f}s/step)"
            )
    if mgr:
        mgr.wait()
    pipe.close()
    shd.deactivate()
    return params, state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--movement", default="baseline", choices=["baseline", "daemon"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    a = ap.parse_args()
    _, _, losses = train(
        a.arch, reduced=a.reduced, steps=a.steps, global_batch=a.batch,
        seq_len=a.seq, movement=a.movement, peak_lr=a.lr,
        ckpt_dir=a.ckpt_dir or None, resume=a.resume,
        num_microbatches=a.microbatches,
    )
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
