import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init).  Everything below is ordinary code.
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import sys  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models import nn  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime import sharding as shd  # noqa: E402

"""Multi-pod dry-run: ``lower() + compile()`` every (arch x shape x mesh)
cell with abstract inputs (ShapeDtypeStruct — no allocation), prove the
memory fits, and extract the roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --cell train_4k --mesh 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every live cell, both meshes

Each --all sub-cell runs in its own subprocess (isolation: one XLA OOM or
assert cannot take down the batch; also keeps per-compile memory bounded on
the 1-core CPU container).
"""


def _abstract(specs, dtype):
    return nn.abstract_params(specs, dtype)


def _memory_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        out = {}
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            if hasattr(ma, k):
                out[k] = int(getattr(ma, k))
        return out
    except Exception as e:  # XLA:CPU may not implement it
        return {"error": str(e)}


def auto_k(cfg, cell, n_dp: int, reduced: bool) -> int:
    if reduced:
        return 1
    return steps.auto_microbatches(cfg, cell.seq_len, cell.global_batch, n_dp)


def run_cell(arch: str, cell_name: str, mesh_spec: str, *, movement: str = "baseline",
             reduced: bool = False, save_hlo: str = "", fsdp: bool = True,
             remat: str = "", params_dtype: str = "", microbatches: int = 0,
             cache_shard: str = "seq", ssm_algo: str = "") -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if remat:
        cfg = __import__("dataclasses").replace(cfg, remat=remat)
    if ssm_algo:
        cfg = __import__("dataclasses").replace(cfg, ssm_algo=ssm_algo)
    cell = SHAPES[cell_name]
    if reduced:
        import dataclasses
        cell = dataclasses.replace(cell, seq_len=128, global_batch=max(8, len(jax.devices()) // 8))

    mesh = mesh_lib.parse_mesh(mesh_spec)
    n_dev = mesh.size
    rules = shd.base_rules(mesh, fsdp=fsdp, cache_shard=cache_shard)
    shd.activate(mesh, rules)

    rec = {
        "arch": arch, "cell": cell_name, "mesh": mesh_spec, "kind": cell.kind,
        "movement": movement, "n_devices": n_dev, "ok": False,
    }
    t0 = time.time()
    try:
        specs = M.model_specs(cfg)
        psh = shd.sharding_for_specs(mesh, rules, specs)

        if cell.kind == "train":
            batch = M.input_specs(cfg, cell)
            bsh = shd.batch_sharding(mesh, rules, batch)
            n_dp = n_dev // mesh.shape.get("model", 1)
            k = microbatches or auto_k(cfg, cell, n_dp, reduced)
            rec["microbatches"] = k
            step = steps.make_train_step(cfg, movement=movement, num_microbatches=k)
            if movement == "daemon":
                from repro.core import movement as mv

                params = _abstract(specs, jnp.bfloat16)  # working copy on the wire
                master = _abstract(specs, jnp.float32)
                opt = mv.init_abstract(master)
                opt_sh = mv.state_shardings(psh, NamedSharding(mesh, P()))
            else:
                pdt = jnp.dtype(params_dtype) if params_dtype else jnp.float32
                params = _abstract(specs, pdt)
                opt = adamw.init_abstract(params)
                opt_sh = adamw.AdamWState(NamedSharding(mesh, P()), psh, psh)
            jitted = jax.jit(
                step, in_shardings=(psh, opt_sh, bsh), donate_argnums=(0, 1)
            )
            lowered = jitted.lower(params, opt, batch)
        elif cell.kind == "prefill":
            params = _abstract(specs, jnp.bfloat16)
            batch = M.input_specs(cfg, cell)
            bsh = shd.batch_sharding(mesh, rules, batch)
            jitted = jax.jit(steps.make_prefill_step(cfg), in_shardings=(psh, bsh))
            lowered = jitted.lower(params, batch)
        else:  # decode
            params = _abstract(specs, jnp.bfloat16)
            inp = M.input_specs(cfg, cell)
            csh = shd.sharding_for_specs(mesh, rules, M.cache_specs(cfg, cell.global_batch, cell.seq_len))
            tok_sh = shd.batch_sharding(mesh, rules, inp["token"])
            pos_sh = NamedSharding(mesh, P())
            jitted = jax.jit(
                steps.make_decode_step(cfg),
                in_shardings=(psh, csh, tok_sh, pos_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params, inp["cache"], inp["token"], inp["pos"])

        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        roof, coll = rl.analyze(compiled, n_dev)
        rec.update(roof.as_dict())
        rec.update(rl.xla_raw_cost(compiled))
        rec["collectives"] = {
            "operand_bytes": coll.operand_bytes,
            "result_bytes": coll.result_bytes,
            "counts": coll.counts,
        }
        rec["memory_analysis"] = _memory_analysis(compiled)
        if not reduced:
            rec["model_flops"] = rl.model_flops(cfg, cell)
            rec["n_params"] = M.param_count(cfg)
            rec["n_params_active"] = M.param_count(cfg, active_only=True)
            if rec["flops"]:
                # per-device HLO flops x n_dev vs global model flops
                rec["model_flops_ratio"] = rec["model_flops"] / (rec["flops"] * n_dev)
        rec["ok"] = True

        if save_hlo:
            Path(save_hlo).parent.mkdir(parents=True, exist_ok=True)
            Path(save_hlo).write_text(compiled.as_text())

        print(f"== {arch} / {cell_name} / {mesh_spec} / {movement} ==")
        print(f"memory_analysis: {rec['memory_analysis']}")
        print(
            f"cost_analysis: flops={rec['flops']:.3e} bytes={rec['hbm_bytes']:.3e} "
            f"coll={rec['collective_bytes']:.3e}"
        )
        print(
            f"terms: compute={rec['t_compute_s']:.4f}s memory={rec['t_memory_s']:.4f}s "
            f"collective={rec['t_collective_s']:.4f}s -> {rec['bottleneck']}"
        )
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"== {arch} / {cell_name} / {mesh_spec} FAILED: {rec['error']}", file=sys.stderr)
    finally:
        shd.deactivate()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--cell", default="")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--movement", default="baseline", choices=["baseline", "daemon"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--remat", default="")
    ap.add_argument("--params-dtype", default="")
    ap.add_argument("--microbatches", type=int, default=0, help="0 = auto")
    ap.add_argument("--cache-shard", default="seq", choices=["seq", "dh"])
    ap.add_argument("--ssm-algo", default="", choices=["", "scan", "ssd"])
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--all", action="store_true", help="run every live cell x both meshes via subprocesses")
    ap.add_argument("--archs", default="", help="comma list filter for --all")
    args = ap.parse_args()

    if args.all:
        import subprocess

        from repro.configs import ARCHS

        outdir = Path(args.out)
        outdir.mkdir(parents=True, exist_ok=True)
        archs = args.archs.split(",") if args.archs else list(ARCHS)
        jobs = []
        for arch in archs:
            cfg = get_config(arch)
            for cell in cfg.live_cells():
                for mesh_spec in ("16x16", "2x16x16"):
                    jobs.append((arch, cell.name, mesh_spec))
        failures = 0
        for i, (arch, cell, mesh_spec) in enumerate(jobs):
            tag = f"{arch}_{cell}_{mesh_spec}_{args.movement}"
            outfile = outdir / f"{tag}.json"
            if outfile.exists() and json.loads(outfile.read_text()).get("ok"):
                print(f"[{i+1}/{len(jobs)}] {tag}: cached ok")
                continue
            hlo_dir = outdir.parent / "hlo"
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--cell", cell, "--mesh", mesh_spec,
                "--movement", args.movement, "--out", str(outdir),
                "--save-hlo", str(hlo_dir / f"{tag}.hlo"),
            ]
            if args.no_fsdp:
                cmd.append("--no-fsdp")
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True)
            ok = outfile.exists() and json.loads(outfile.read_text()).get("ok")
            failures += 0 if ok else 1
            print(
                f"[{i+1}/{len(jobs)}] {tag}: {'ok' if ok else 'FAIL'} ({time.time()-t0:.0f}s)",
                flush=True,
            )
            if not ok:
                sys.stderr.write((r.stdout or "")[-2000:] + (r.stderr or "")[-3000:] + "\n")
        print(f"dry-run batch done: {len(jobs) - failures}/{len(jobs)} ok")
        sys.exit(1 if failures else 0)

    rec = run_cell(
        args.arch, args.cell, args.mesh, movement=args.movement,
        reduced=args.reduced, save_hlo=args.save_hlo, fsdp=not args.no_fsdp,
        remat=args.remat, params_dtype=args.params_dtype,
        microbatches=args.microbatches, cache_shard=args.cache_shard,
        ssm_algo=args.ssm_algo,
    )
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    tag = f"{args.arch}_{args.cell}_{args.mesh}_{args.movement}"
    if args.reduced:
        tag += "_reduced"
    (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    sys.exit(0 if rec.get("ok") else 1)


if __name__ == "__main__":
    main()
