"""jit-able step functions: train_step (fwd + bwd + AdamW) and the two
serving steps (prefill / decode).  The ``movement`` argument selects the
data-movement scheme for gradients & parameters:

  "baseline" — plain GSPMD: gradients all-reduced implicitly over DP axes,
               optimizer state mirrors params.
  "daemon"   — the paper's engine (core/movement): ZeRO-sharded optimizer,
               chunked + prioritized + link-compressed page collectives.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim import adamw, schedule


def auto_microbatches(cfg: ModelConfig, seq_len: int, global_batch: int, n_dp: int,
                      budget_bytes: float = 6e9) -> int:
    """Pick the gradient-accumulation factor so the per-device activation
    stash (~2.5 bytes/elem x layers x local tokens x d_model: the residual
    saved per scanned layer plus policy-saved dot outputs) fits the budget.
    Power of two, at most one sequence per microbatch per DP shard."""
    local_batch = max(1, global_batch // max(n_dp, 1))
    layers = cfg.num_layers + cfg.enc_layers + cfg.dec_layers
    stash = 2.5 * layers * local_batch * seq_len * cfg.d_model
    k = 1
    while stash / k > budget_bytes and k < local_batch:
        k *= 2
    return k


def _microbatched_grads(cfg: ModelConfig, params, batch, k: int):
    """Mean loss/grads over k sequential microbatches (activation stash /k)."""
    if k <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch), has_aux=True
        )(params)
        return grads, metrics

    mb = jax.tree.map(lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(acc, mbatch):
        g_acc, loss_acc = acc
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, mbatch), has_aux=True
        )(params)
        g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
        return (g_acc, loss_acc + loss), metrics

    (g_sum, loss_sum), metrics = jax.lax.scan(body, (g0, jnp.zeros(())), mb)
    grads = jax.tree.map(lambda g: g / k, g_sum)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    metrics["loss"] = loss_sum / k
    return grads, metrics


def make_train_step(
    cfg: ModelConfig,
    *,
    peak_lr: float = 3e-4,
    total_steps: int = 10_000,
    movement: str = "baseline",
    movement_cfg: Optional[Any] = None,
    num_microbatches: int = 1,
) -> Callable:
    warmup = max(1, min(100, total_steps // 10))
    sched = schedule.make(
        cfg.schedule, peak_lr=peak_lr, total_steps=total_steps, warmup_steps=warmup
    )

    if movement == "daemon":
        from repro.core import movement as mv

        return mv.make_daemon_train_step(
            cfg, sched=sched, engine_cfg=movement_cfg, num_microbatches=num_microbatches
        )

    def train_step(params, opt_state, batch):
        grads, metrics = _microbatched_grads(cfg, params, batch, num_microbatches)
        lr = sched(opt_state.step)
        params, opt_state, om = adamw.update(grads, opt_state, params, lr)
        return params, opt_state, {**metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, token, pos):
        logits, cache = M.decode_step(cfg, params, cache, token, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, cache

    return decode_step
