"""Production mesh factory.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and the dry-run
must set XLA_FLAGS before that).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Optional[Tuple[str, ...]] = None):
    """Arbitrary mesh (tests, elastic reconfiguration).  Slices the device
    list so a 16x16 mesh also works in the 512-fake-device dry-run process."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):]
    n = int(np.prod(shape))
    axis_type = getattr(jax.sharding, "AxisType", None)  # absent before jax 0.5
    if axis_type is None:
        return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
    return jax.make_mesh(shape, axes, (axis_type.Auto,) * len(axes),
                         devices=jax.devices()[:n])


def parse_mesh(spec: str):
    """'16x16' -> (data, model); '2x16x16' -> (pod, data, model)."""
    dims = tuple(int(x) for x in spec.lower().split("x"))
    return make_mesh(dims)
