"""Serving driver: batched prefill -> decode with the DaeMon movement engine
on the KV/weight path.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import movement as mv
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.models import nn
from repro.runtime import sharding as shd


def serve(
    arch: str,
    *,
    reduced: bool = True,
    batch: int = 4,
    prompt_len: int = 64,
    gen_tokens: int = 32,
    movement: str = "daemon",
    mesh_shape=None,
    seed: int = 0,
):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_mesh(mesh_shape or (1, 1))
    rules = shd.base_rules(mesh, fsdp=True)
    shd.activate(mesh, rules)
    specs = M.model_specs(cfg)

    master = nn.init_params(specs, jax.random.key(seed))
    mv_cfg = mv.DAEMON_DEFAULT if movement == "daemon" else mv.BASELINE
    params = mv.working_copy(master, mv_cfg) if movement == "daemon" else master

    rng = np.random.default_rng(seed)
    total_len = prompt_len + gen_tokens
    batch_in = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch_in["patches"] = jnp.zeros((batch, cfg.num_prefix_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch_in["frames"] = jnp.zeros((batch, prompt_len, cfg.d_model), jnp.bfloat16)

    # prefill builds a cache sized for the prompt; decode appends in a cache
    # sized total_len: re-home the prefill cache into the bigger buffers
    t0 = time.time()
    logits, cache = jax.jit(lambda p, b: M.prefill(cfg, p, b))(params, batch_in)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    cache = _grow_cache(cfg, cache, total_len)
    decode = jax.jit(steps_lib.make_decode_step(cfg), donate_argnums=(1,))

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    prefix = cfg.num_prefix_tokens if cfg.family == "vlm" else 0
    t0 = time.time()
    for i in range(gen_tokens - 1):
        pos = jnp.asarray(prompt_len + prefix + i, jnp.int32)
        tok, logits, cache = decode(params, cache, tok, pos)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    shd.deactivate()
    toks = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    return {
        "tokens": toks,
        "prefill_s": t_prefill,
        "decode_s_per_token": t_decode / max(gen_tokens - 1, 1),
        "tokens_per_s": batch * (gen_tokens - 1) / max(t_decode, 1e-9),
    }


def _grow_cache(cfg, cache, total_len: int):
    """Pad seq-dim (axis 2: [L/inv, B, S, ...]) cache buffers up to
    total_len.  SWA ring caches are window-sized and stay put; SSM states
    have no seq dim and are untouched."""

    def grow(x):
        if x.ndim < 3:
            return x
        if cfg.attn_kind == "swa" and x.shape[2] == cfg.window:
            return x  # ring buffer
        if x.ndim >= 4 and x.shape[2] < total_len:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, total_len - x.shape[2])
            return jnp.pad(x, pad)
        if x.ndim == 3 and cfg.attn_kind == "mla" and x.shape[1] < total_len:
            return x  # MLA caches are (L, B, S, R): handled by the 4-D branch
        return x

    return jax.tree.map(grow, cache)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--movement", default="daemon", choices=["baseline", "daemon"])
    a = ap.parse_args()
    r = serve(
        a.arch, reduced=a.reduced, batch=a.batch, prompt_len=a.prompt_len,
        gen_tokens=a.gen, movement=a.movement,
    )
    print(
        f"prefill {r['prefill_s']:.2f}s; decode {r['decode_s_per_token']*1e3:.1f} ms/tok; "
        f"{r['tokens_per_s']:.1f} tok/s; generated shape {r['tokens'].shape}"
    )


if __name__ == "__main__":
    main()
