"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` visits every instruction ONCE —
``while`` bodies (i.e. every ``lax.scan`` over layers) are counted for a
single iteration, undercounting a 40-layer model's FLOPs by ~40x (verified
experimentally; see EXPERIMENTS.md §Methodology).  This module re-derives

    flops            — dot/conv 2*M*N*K + elementwise, x loop trip counts
    hbm_bytes        — per-instruction operand+result bytes at fusion
                       boundaries (the HBM-traffic model), x trip counts
    collective bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       x trip counts

from ``compiled.as_text()`` (post-SPMD, so shapes are per-device).  Trip
counts come from the ``known_trip_count`` backend_config emitted for scans,
with a fallback to the loop-condition constant.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# NOTE: shape part is lazy `.*?` (NOT `[^=]*?`): tuple shapes with >= 6
# elements embed `/*index=5*/` comments containing '='.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?.*?)\s([\w\-]+)\("
)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n["\s:]+["\']?(\d+)')
_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "exponential", "log",
    "tanh", "sqrt", "rsqrt", "negate", "abs", "maximum", "minimum", "compare",
    "select", "and", "or", "xor", "not", "convert", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "sine", "cosine", "logistic",
    "expm1", "log1p", "sign", "clamp", "remainder", "atan2", "is-finite",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "exponential-minus-one",
}
ZERO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)


def _shape_info(s: str) -> Tuple[int, Optional[List[int]]]:
    """'f32[8,64]{1,0}' or '(s32[], f32[4])' -> (bytes, dims or None-for-tuple)."""
    s = s.strip()
    if s.startswith("("):
        total = 0
        for dt, dims in _SHAPE_RE.findall(s):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES.get(dt, 4)
        return total, None
    m = _SHAPE_RE.match(s)
    if not m:
        return 0, None
    dt, dims = m.groups()
    dl = [int(d) for d in dims.split(",") if d]
    n = 1
    for d in dl:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4), dl


@dataclass
class Op:
    name: str
    opcode: str
    result_bytes: int
    result_dims: Optional[List[int]]
    operands: List[str]
    attrs: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    params: Dict[str, Tuple[int, Optional[List[int]]]] = field(default_factory=dict)
    ops: List[Op] = field(default_factory=list)
    table: Dict[str, Tuple[int, Optional[List[int]]]] = field(default_factory=dict)


def _split_args(line: str, start: int) -> Tuple[List[str], int]:
    """Extract top-level comma-separated args of the paren group at `start`."""
    depth = 0
    args, cur = [], []
    i = start
    while i < len(line):
        ch = line[i]
        if ch in "([{":
            depth += 1
            if depth > 1:
                cur.append(ch)
        elif ch in ")]}":
            depth -= 1
            if depth == 0:
                args.append("".join(cur))
                return args, i
            cur.append(ch)
        elif ch == "," and depth == 1:
            args.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
        i += 1
    return args, i


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(2))
                # depth-aware split: shapes contain commas (f32[2,4096,1])
                params_raw, _ = _split_args("(" + m.group(3) + ")", 0)
                for p in params_raw:
                    p = p.strip()
                    if ":" in p:
                        nme, sh = p.split(":", 1)
                        cur.params[nme.strip().lstrip("%")] = _shape_info(sh)
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if line.strip() == "}":
            cur.table.update(cur.params)
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape_s, opcode = m.group(1), m.group(2), m.group(3)
        rb, dims = _shape_info(shape_s)
        paren = line.find(opcode + "(") + len(opcode)
        raw_args, endi = _split_args(line, paren)
        # strip /*index=N*/ comments; keep ALL positions so operand index i
        # maps to called-computation parameter i (non-%ref args -> "")
        operands = []
        for a in raw_args:
            a = re.sub(r"/\*.*?\*/", "", a).strip()
            operands.append(a.lstrip("%") if a.startswith("%") else "")
        op = Op(name, opcode, rb, dims, operands, line[endi:],
                is_root=line.lstrip().startswith("ROOT"))
        cur.ops.append(op)
        cur.table[name] = (rb, dims)
    return comps, entry


class Analyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: Dict[str, Tuple[float, float, Dict[str, float], Dict[str, int]]] = {}

    # ------------------------------------------------------------------
    def _trip_count(self, op: Op) -> int:
        m = _TRIP_RE.search(op.attrs)
        if m:
            return int(m.group(1))
        # fallback: largest s32 constant in the condition computation
        cm = re.search(r"condition=%?([\w.\-]+)", op.attrs)
        if cm and cm.group(1) in self.comps:
            consts = []
            for o in self.comps[cm.group(1)].ops:
                if o.opcode == "constant":
                    c2 = re.search(r"\((\d+)\)", o.attrs)
                    if c2:
                        consts.append(int(c2.group(1)))
            if consts:
                return max(consts)
        return 1

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        k = 1
        if m and op.operands:
            lhs = comp.table.get(op.operands[0])
            if lhs and lhs[1]:
                for d in m.group(1).split(","):
                    if d and int(d) < len(lhs[1]):
                        k *= lhs[1][int(d)]
        out = 1
        for d in op.result_dims or []:
            out *= d
        return 2.0 * out * k

    def _conv_flops(self, comp: Computation, op: Op) -> float:
        # flops ~= 2 * prod(result) * prod(kernel spatial+input feature)
        rhs = comp.table.get(op.operands[1]) if len(op.operands) > 1 else None
        out = 1
        for d in op.result_dims or []:
            out *= d
        kprod = 1
        if rhs and rhs[1]:
            dims = rhs[1]
            # kernel: spatial... x in_ch x out_ch (approx: drop the largest=out)
            if len(dims) >= 2:
                kprod = 1
                for d in dims:
                    kprod *= d
                kprod //= max(dims)
        return 2.0 * out * kprod

    def _fusion_bytes(self, comp: Computation, op: Op, body_name: str) -> float:
        """HBM traffic at a fusion boundary, scan-carry aware:

        - a fusion *parameter* consumed only by dynamic-slice ops is charged
          the slice bytes (reading a layer slice of a stacked array), not the
          full array;
        - a parameter that is only the *destination* of dynamic-update-slice
          is charged 0 for the read (in-place aliased carry);
        - a fusion whose ROOT is dynamic-update-slice writes only the update
          region, not the full (aliased) result.
        """
        body = self.comps.get(body_name)
        if body is None:
            return op.result_bytes + sum(
                comp.table.get(r, (0, None))[0] for r in op.operands
            )
        passthrough = {"bitcast", "reshape", "copy", "convert", "transpose", "reduce-precision"}
        consumers_of: Dict[str, List[Op]] = {}
        for o in body.ops:
            for r in o.operands:
                consumers_of.setdefault(r, []).append(o)

        def frontier(name: str, depth: int = 0):
            """(consumer, via-operand-name) pairs reached through pass-throughs."""
            out = []
            for o in consumers_of.get(name, []):
                if o.opcode in passthrough and depth < 8:
                    out.extend(frontier(o.name, depth + 1))
                else:
                    out.append((o, name))
            return out

        param_names = list(body.params)
        total = 0.0
        for i, ref in enumerate(op.operands):
            full = comp.table.get(ref, (0, None))[0]
            pname = param_names[i] if i < len(param_names) else None
            if pname is None:
                total += full
                continue
            cons = frontier(pname)
            if cons and all(o.opcode == "dynamic-slice" for o, _ in cons):
                total += sum(o.result_bytes for o, _ in cons)
            elif cons and all(
                o.opcode == "dynamic-update-slice"
                and o.operands
                and o.operands[0] == via  # destination role only
                for o, via in cons
            ):
                # consumed only as DUS destination(s): the unwritten region is
                # aliased, only the written region counts (charged on result)
                total += 0.0
            else:
                total += full
        # result side: a DUS root writes only the update region
        root = next((o for o in body.ops if o.is_root), None)
        if root is not None and root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
            total += body.table.get(root.operands[1], (op.result_bytes, None))[0]
        else:
            total += op.result_bytes
        return total

    def analyze_comp(self, name: str, *, fused: bool = False):
        """Returns (flops, bytes, coll_bytes_by_kind, coll_counts)."""
        key = name + ("#f" if fused else "")
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        if comp is None:
            return (0.0, 0.0, {}, {})
        flops = 0.0
        hbm = 0.0
        coll: Dict[str, float] = {}
        counts: Dict[str, int] = {}

        for op in comp.ops:
            oc = op.opcode
            base_kind = oc[:-6] if oc.endswith("-start") else oc
            # ---- recursion ----
            if oc == "while":
                trips = self._trip_count(op)
                for called in _CALLED_RE.findall(op.attrs):
                    f, b, c, n = self.analyze_comp(called)
                    flops += trips * f
                    hbm += trips * b
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0.0) + trips * v
                    for k, v in n.items():
                        counts[k] = counts.get(k, 0) + trips * v
                continue
            if oc == "conditional":
                m = _BRANCH_RE.search(op.attrs)
                branches = (
                    [b.strip().lstrip("%") for b in m.group(1).split(",")] if m else []
                )
                best = (0.0, 0.0, {}, {})
                for bname in branches:
                    r = self.analyze_comp(bname)
                    if r[0] >= best[0]:
                        best = r
                flops += best[0]
                hbm += best[1]
                for k, v in best[2].items():
                    coll[k] = coll.get(k, 0.0) + v
                continue
            if oc == "fusion":
                called = _CALLED_RE.search(op.attrs)
                if called:
                    f, _, c, n = self.analyze_comp(called.group(1), fused=True)
                    flops += f
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0.0) + v
                    for k, v in n.items():
                        counts[k] = counts.get(k, 0) + v
                    hbm += self._fusion_bytes(comp, op, called.group(1))
                else:
                    hbm += op.result_bytes + sum(
                        comp.table.get(r, (0, None))[0] for r in op.operands
                    )
                continue
            if oc == "call":
                called = _CALLED_RE.search(op.attrs)
                if called:
                    f, b, c, n = self.analyze_comp(called.group(1))
                    flops += f
                    hbm += b
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0.0) + v
                continue

            # ---- collectives ----
            if base_kind in COLLECTIVES:
                ob = sum(comp.table.get(r, (0, None))[0] for r in op.operands)
                if ob == 0:
                    ob = op.result_bytes
                coll[base_kind] = coll.get(base_kind, 0.0) + ob
                counts[base_kind] = counts.get(base_kind, 0) + 1
                hbm += ob + op.result_bytes
                continue

            # ---- flops ----
            out_elems = 1
            for d in op.result_dims or []:
                out_elems *= d
            if oc == "dot":
                flops += self._dot_flops(comp, op)
            elif oc == "convolution":
                flops += self._conv_flops(comp, op)
            elif oc in ("reduce", "reduce-window"):
                ib = sum(comp.table.get(r, (0, None))[0] for r in op.operands)
                flops += ib / 4.0  # ~1 flop per input element (dtype-agnostic approx)
            elif oc in ELEMENTWISE:
                flops += out_elems

            # ---- bytes ----
            if not fused and oc not in ZERO_BYTES and not oc.endswith("-done"):
                if oc in ("dynamic-slice", "slice"):
                    hbm += 2 * op.result_bytes  # read slice region + write result
                elif oc == "dynamic-update-slice":
                    upd = (
                        comp.table.get(op.operands[1], (0, None))[0]
                        if len(op.operands) > 1
                        else op.result_bytes
                    )
                    hbm += 2 * upd  # read update + write region (dest aliased)
                elif oc == "broadcast":
                    hbm += op.result_bytes
                else:
                    hbm += op.result_bytes + sum(
                        comp.table.get(r, (0, None))[0] for r in op.operands
                    )

        res = (flops, hbm, coll, counts)
        self._memo[key] = res
        return res

    def analyze(self):
        if self.entry is None:
            # fall back: the largest computation
            self.entry = max(self.comps, key=lambda c: len(self.comps[c].ops))
        return self.analyze_comp(self.entry)


def analyze_text(text: str):
    """Returns dict(flops=..., hbm_bytes=..., collective_bytes={kind: b},
    collective_counts={kind: n}) — all per-device, trip-count corrected."""
    a = Analyzer(text)
    flops, hbm, coll, counts = a.analyze()
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": coll,
        "collective_counts": counts,
    }
