"""Pallas TPU kernel: per-block absmax int8 quantize / dequantize.

This is DaeMon's link-compression unit on TPU: it fuses into the
pre-collective copy of page-granularity transfers (bulk weight all-gathers,
gradient reduce-scatters, KV-page migrations).  Tiling: rows x 512-lane
tiles in VMEM; each 128-lane sub-block reduces its absmax on the VPU, so the
MXU stays free for the overlapped compute.

Layout contract: input (R, C), C % BLOCK == 0; grid (R/TR, C/TC); every
VMEM tile holds TC/BLOCK complete quantization blocks (TC % BLOCK == 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128  # quantization block (lane-aligned)
TILE_R = 256  # rows per VMEM tile
TILE_C = 512  # columns per VMEM tile (4 quant blocks)


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (TR, TC)
    tr, tc = x.shape
    xb = x.reshape(tr, tc // BLOCK, BLOCK)
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = absmax / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xb / safe), -127, 127)
    q_ref[...] = q.reshape(tr, tc).astype(jnp.int8)
    s_ref[...] = scale[..., 0].astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref, *, out_dtype):
    q = q_ref[...].astype(jnp.float32)  # (TR, TC)
    s = s_ref[...]  # (TR, TC/BLOCK)
    tr, tc = q.shape
    x = q.reshape(tr, tc // BLOCK, BLOCK) * s[..., None]
    x_ref[...] = x.reshape(tr, tc).astype(out_dtype)


def _tiles(r: int, c: int):
    tr = min(TILE_R, r)
    tc = min(TILE_C, c)
    while r % tr:
        tr //= 2
    while c % tc:
        tc //= 2
    tc = max(tc, BLOCK)
    return max(tr, 1), tc


def quantize_pallas(x: jax.Array, *, interpret: bool = False):
    """x: (R, C) -> (q int8 (R, C), scales f32 (R, C/BLOCK))."""
    r, c = x.shape
    assert c % BLOCK == 0, f"C={c} must be a multiple of {BLOCK}"
    tr, tc = _tiles(r, c)
    grid = (r // tr, c // tc)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tr, tc), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tr, tc // BLOCK), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.int8),
            jax.ShapeDtypeStruct((r, c // BLOCK), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def dequantize_pallas(q: jax.Array, scales: jax.Array, dtype=jnp.float32,
                      *, interpret: bool = False):
    r, c = q.shape
    assert c % BLOCK == 0 and scales.shape == (r, c // BLOCK)
    tr, tc = _tiles(r, c)
    grid = (r // tr, c // tc)
    kern = functools.partial(_dequant_kernel, out_dtype=dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tr, tc // BLOCK), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), dtype),
        interpret=interpret,
    )(q, scales)
