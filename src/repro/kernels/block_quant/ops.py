"""jit'd public wrappers for block quantization.

On TPU the Pallas kernel runs natively; elsewhere (this CPU container, and
inside the dry-run so cost_analysis stays transparent) the pure-jnp reference
path is used — numerically identical (tests assert exact equality).

Also the kernel's trace-capture shim (:func:`trace_geometry`): the grid /
BlockSpec index-map math of ``quantize_pallas`` mirrored into a jax-free
:class:`~repro.capture.geometry.KernelGeometry` (DESIGN.md §2.8; drift
against the kernel is locked by tests/test_capture.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.block_quant import ref
from repro.kernels.block_quant.block_quant import (
    BLOCK, dequantize_pallas, quantize_pallas,
)


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("block", "use_kernel", "interpret"))
def quantize(x: jax.Array, block: int = BLOCK, *, use_kernel: bool = False,
             interpret: bool = False):
    """Flattens to 2-D (rows, C), quantizes per block along the last axis."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim != 2 else x
    if (use_kernel or _on_tpu()) and block == BLOCK and x2.shape[-1] % BLOCK == 0:
        q, s = quantize_pallas(x2, interpret=interpret)
    else:
        q, s = ref.quantize_ref(x2, block)
    return q.reshape(shape), s.reshape(*shape[:-1], shape[-1] // block)


@functools.partial(jax.jit, static_argnames=("dtype", "use_kernel", "interpret"))
def dequantize(q: jax.Array, scales: jax.Array, dtype=jnp.float32, *,
               use_kernel: bool = False, interpret: bool = False):
    shape = q.shape
    q2 = q.reshape(-1, shape[-1]) if q.ndim != 2 else q
    s2 = scales.reshape(q2.shape[0], -1)
    if (use_kernel or _on_tpu()) and q2.shape[-1] % BLOCK == 0 and (
        q2.shape[-1] // s2.shape[-1] == BLOCK
    ):
        x = dequantize_pallas(q2, s2, dtype, interpret=interpret)
    else:
        x = ref.dequantize_ref(q2, s2, dtype)
    return x.reshape(shape)


def trace_geometry(*, r: int, c: int, variant: str = "quant"):
    """Capture shim: the exact grid + index maps of ``quantize_pallas`` for
    an (R, C) f32 input — grid (R/TR, C/TC) with the column-tile axis
    innermost, reading f32 tiles and writing the int8 payload + one f32
    absmax scale per quantization block."""
    from repro.capture.geometry import KernelGeometry, Operand
    from repro.kernels.block_quant.block_quant import _tiles

    assert c % BLOCK == 0, f"C={c} must be a multiple of {BLOCK}"
    tr, tc = _tiles(r, c)
    grid = (r // tr, c // tc)

    def tile_map(i, j):
        return (i, j)

    # per grid step: abs + max-reduce + scale + round + clip over the tile
    flops = 5.0 * tr * tc
    return KernelGeometry(
        kernel="block_quant", variant=variant, grid=grid,
        operands=(
            Operand("x", (r, c), (tr, tc), tile_map,
                    payload="f32_act_sparse"),
            Operand("q", (r, c), (tr, tc), tile_map, elem_bytes=1,
                    is_output=True, payload="int8_quant"),
            Operand("scales", (r, c // BLOCK), (tr, tc // BLOCK), tile_map,
                    is_output=True, payload="f32_scales"),
        ),
        flops_per_step=flops,
    )


def wire_bytes(shape, dtype_bytes: int = 2, block: int = BLOCK) -> int:
    """Compressed wire size: int8 payload + f32 scale per block."""
    import numpy as np

    n = int(np.prod(shape))
    return n + 4 * (n // block)
