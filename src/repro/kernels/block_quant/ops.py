"""jit'd public wrappers for block quantization.

On TPU the Pallas kernel runs natively; elsewhere (this CPU container, and
inside the dry-run so cost_analysis stays transparent) the pure-jnp reference
path is used — numerically identical (tests assert exact equality).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.block_quant import ref
from repro.kernels.block_quant.block_quant import (
    BLOCK, dequantize_pallas, quantize_pallas,
)


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("block", "use_kernel", "interpret"))
def quantize(x: jax.Array, block: int = BLOCK, *, use_kernel: bool = False,
             interpret: bool = False):
    """Flattens to 2-D (rows, C), quantizes per block along the last axis."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim != 2 else x
    if (use_kernel or _on_tpu()) and block == BLOCK and x2.shape[-1] % BLOCK == 0:
        q, s = quantize_pallas(x2, interpret=interpret)
    else:
        q, s = ref.quantize_ref(x2, block)
    return q.reshape(shape), s.reshape(*shape[:-1], shape[-1] // block)


@functools.partial(jax.jit, static_argnames=("dtype", "use_kernel", "interpret"))
def dequantize(q: jax.Array, scales: jax.Array, dtype=jnp.float32, *,
               use_kernel: bool = False, interpret: bool = False):
    shape = q.shape
    q2 = q.reshape(-1, shape[-1]) if q.ndim != 2 else q
    s2 = scales.reshape(q2.shape[0], -1)
    if (use_kernel or _on_tpu()) and q2.shape[-1] % BLOCK == 0 and (
        q2.shape[-1] // s2.shape[-1] == BLOCK
    ):
        x = dequantize_pallas(q2, s2, dtype, interpret=interpret)
    else:
        x = ref.dequantize_ref(q2, s2, dtype)
    return x.reshape(shape)


def wire_bytes(shape, dtype_bytes: int = 2, block: int = BLOCK) -> int:
    """Compressed wire size: int8 payload + f32 scale per block."""
    import numpy as np

    n = int(np.prod(shape))
    return n + 4 * (n // block)
