from repro.kernels.block_quant.ops import dequantize, quantize

__all__ = ["quantize", "dequantize"]
