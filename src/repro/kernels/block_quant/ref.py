"""Pure-jnp oracle for per-block absmax int8 quantization (link compression).

Blocks are contiguous runs of ``block`` elements along the last axis; each
block gets one f32 scale (absmax / 127).  Wire format = int8 payload + f32
scales: 4096 B bf16 -> 2048 + 64 B  (~1.94x reduction incl. scales).
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x: jnp.ndarray, block: int = 128):
    """x: (..., C) with C % block == 0 -> (q int8 (..., C), scales f32 (..., C/block))."""
    orig_shape = x.shape
    c = orig_shape[-1]
    assert c % block == 0, (c, block)
    xb = x.astype(jnp.float32).reshape(*orig_shape[:-1], c // block, block)
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = absmax / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xb / safe), -127, 127).astype(jnp.int8)
    return q.reshape(orig_shape), scale[..., 0].astype(jnp.float32)


def dequantize_ref(q: jnp.ndarray, scales: jnp.ndarray, dtype=jnp.float32):
    """Inverse of quantize_ref."""
    orig_shape = q.shape
    c = orig_shape[-1]
    block = c // scales.shape[-1]
    qb = q.reshape(*orig_shape[:-1], scales.shape[-1], block).astype(jnp.float32)
    x = qb * scales[..., None]
    return x.reshape(orig_shape).astype(dtype)
