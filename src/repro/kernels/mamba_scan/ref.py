"""Sequential-scan oracle for the selective SSM recurrence:

    h_t = da_t * h_{t-1} + dbx_t        h: (B, D, N)
    y_t = sum_n h_t[:, :, n] * C_t[:, n] (+ D_skip * x handled by caller)

Inputs follow the mamba1 discretization: da = exp(dt * A)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(dt, a, bmat, cmat, x):
    """dt: (B,S,D) f32; a: (D,N) (negative); bmat/cmat: (B,S,N); x: (B,S,D).
    Returns y: (B,S,D) f32, h_last: (B,D,N)."""
    da = jnp.exp(dt[..., None] * a)  # (B,S,D,N)
    dbx = (dt * x)[..., None] * bmat[:, :, None, :]  # (B,S,D,N)

    def step(h, inp):
        da_t, dbx_t, c_t = inp
        h = da_t * h + dbx_t  # (B,D,N)
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    b, s, d = dt.shape
    n = a.shape[1]
    h0 = jnp.zeros((b, d, n), jnp.float32)
    h_last, ys = jax.lax.scan(
        step, h0, (da.swapaxes(0, 1), dbx.swapaxes(0, 1), cmat.swapaxes(0, 1))
    )
    return ys.swapaxes(0, 1), h_last
