"""jit'd wrapper for the selective scan: Pallas kernel on TPU, associative
chunked-scan jnp path elsewhere (models/mamba.py provides the production XLA
path; ref.py the sequential oracle).

Also the kernel's trace-capture shim (:func:`trace_geometry`): the grid /
BlockSpec index-map math of ``selective_scan_pallas`` mirrored into a
jax-free :class:`~repro.capture.geometry.KernelGeometry` (DESIGN.md §2.8;
drift against the kernel is locked by tests/test_capture.py)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan import ref
from repro.kernels.mamba_scan.mamba_scan import CHUNK, TILE_D, selective_scan_pallas


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def selective_scan(dt, a, bmat, cmat, x, *, use_kernel: bool = False,
                   interpret: bool = False):
    if use_kernel or jax.default_backend() == "tpu":
        return selective_scan_pallas(
            dt, a, bmat, cmat, x,
            interpret=interpret or jax.default_backend() != "tpu",
        )
    return ref.selective_scan_ref(dt, a, bmat, cmat, x)


def trace_geometry(*, b: int, s: int, d: int, n: int, chunk: int = CHUNK,
                   tile_d: int = TILE_D, variant: str = "fwd"):
    """Capture shim: the exact grid + index maps of
    ``selective_scan_pallas`` — grid (B, D/TD, S/CHUNK), chunk axis
    innermost and sequential (the SSM state is VMEM-carried across chunks),
    A parked across the chunk loop, B/C re-streamed for every channel
    tile."""
    from repro.capture.geometry import KernelGeometry, Operand

    chunk = min(chunk, s)
    tile_d = min(tile_d, d)
    assert s % chunk == 0 and d % tile_d == 0, (s, chunk, d, tile_d)
    grid = (b, d // tile_d, s // chunk)

    def chunk_map(bi, di, ci):
        return (bi, ci, di)

    def a_map(bi, di, ci):
        return (di, 0)

    def bc_map(bi, di, ci):
        return (bi, ci, 0)

    def h_map(bi, di, ci):
        return (bi, di, 0)

    # per grid step: chunk x (discretize + recurrence + C-projection) on
    # (tile_d, n) tiles — ~8 flops per (t, channel, state) element
    flops = 8.0 * chunk * tile_d * n
    return KernelGeometry(
        kernel="mamba_scan", variant=variant, grid=grid,
        operands=(
            Operand("dt", (b, s, d), (1, chunk, tile_d), chunk_map,
                    payload="f32_pos"),
            Operand("a", (d, n), (tile_d, n), a_map),
            Operand("bmat", (b, s, n), (1, chunk, n), bc_map),
            Operand("cmat", (b, s, n), (1, chunk, n), bc_map),
            Operand("x", (b, s, d), (1, chunk, tile_d), chunk_map),
            Operand("y", (b, s, d), (1, chunk, tile_d), chunk_map,
                    is_output=True),
            Operand("h_last", (b, d, n), (1, tile_d, n), h_map,
                    is_output=True),
        ),
        flops_per_step=flops,
    )
