"""jit'd wrapper for the selective scan: Pallas kernel on TPU, associative
chunked-scan jnp path elsewhere (models/mamba.py provides the production XLA
path; ref.py the sequential oracle)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan import ref
from repro.kernels.mamba_scan.mamba_scan import selective_scan_pallas


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def selective_scan(dt, a, bmat, cmat, x, *, use_kernel: bool = False,
                   interpret: bool = False):
    if use_kernel or jax.default_backend() == "tpu":
        return selective_scan_pallas(
            dt, a, bmat, cmat, x,
            interpret=interpret or jax.default_backend() != "tpu",
        )
    return ref.selective_scan_ref(dt, a, bmat, cmat, x)
