"""Pallas TPU kernel: chunked selective scan (Mamba1-style recurrence).

Grid = (B, D / TD, S / CHUNK) with the sequence-chunk axis innermost and
sequential: the SSM state h (TD, N) persists in VMEM scratch across chunk
steps (reset at chunk 0).  Within a chunk the recurrence is unrolled as a
fori_loop over time steps on VPU-resident (TD, N) tiles — the working set
(CHUNK x TD inputs + TD x N state) stays in VMEM, which is the kernel-level
analogue of the chunked lax.scan the XLA path uses (models/mamba.py).

Discretization (da = exp(dt*A), dbx = dt*x*B) happens in-kernel so the big
(S, D, N) tensors are never materialized in HBM — on TPU this kernel turns
the SSM layer from HBM-bound to VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 128  # time steps per grid step
TILE_D = 256  # channels per grid step


def _scan_kernel(dt_ref, a_ref, b_ref, c_ref, x_ref, y_ref, hlast_ref, h_scr, *,
                 chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    dt = dt_ref[0].astype(jnp.float32)  # (CHUNK, TD)
    a = a_ref[...].astype(jnp.float32)  # (TD, N)
    bm = b_ref[0].astype(jnp.float32)  # (CHUNK, N)
    cm = c_ref[0].astype(jnp.float32)  # (CHUNK, N)
    x = x_ref[0].astype(jnp.float32)  # (CHUNK, TD)

    def step(t, carry):
        h, ys = carry
        da_t = jnp.exp(dt[t][:, None] * a)  # (TD, N)
        dbx_t = (dt[t] * x[t])[:, None] * bm[t][None, :]  # (TD, N)
        h = da_t * h + dbx_t
        y_t = jnp.sum(h * cm[t][None, :], axis=-1)  # (TD,)
        ys = jax.lax.dynamic_update_slice(ys, y_t[None, :], (t, 0))
        return h, ys

    h0 = h_scr[...]
    ys0 = jnp.zeros((chunk, dt.shape[1]), jnp.float32)
    h_out, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_scr[...] = h_out
    y_ref[0] = ys

    @pl.when(ci == n_chunks - 1)
    def _final():
        hlast_ref[0] = h_out


def selective_scan_pallas(dt, a, bmat, cmat, x, *, chunk: int = CHUNK,
                          tile_d: int = TILE_D, interpret: bool = False):
    """dt,x: (B,S,D); a: (D,N); bmat,cmat: (B,S,N) -> (y (B,S,D) f32, h_last (B,D,N))."""
    b, s, d = dt.shape
    n = a.shape[1]
    chunk = min(chunk, s)
    tile_d = min(tile_d, d)
    assert s % chunk == 0 and d % tile_d == 0, (s, chunk, d, tile_d)
    n_chunks = s // chunk
    grid = (b, d // tile_d, n_chunks)

    kern = functools.partial(_scan_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_last = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, tile_d), lambda bi, di, ci: (bi, ci, di)),  # dt
            pl.BlockSpec((tile_d, n), lambda bi, di, ci: (di, 0)),  # a
            pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0)),  # B
            pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0)),  # C
            pl.BlockSpec((1, chunk, tile_d), lambda bi, di, ci: (bi, ci, di)),  # x
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, tile_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, tile_d, n), lambda bi, di, ci: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((tile_d, n), jnp.float32)],
        interpret=interpret,
    )(dt, a, bmat, cmat, x)
    return y, h_last
