# Pallas TPU kernels for the perf-critical layers (validated in interpret
# mode against ref.py oracles on CPU; native on TPU):
#   block_quant     — DaeMon link compression (per-block absmax int8)
#   flash_attention — online-softmax attention (causal / SWA / GQA)
#   mamba_scan      — chunked selective scan (SSM archs)
from repro.kernels import block_quant, flash_attention, mamba_scan

__all__ = ["block_quant", "flash_attention", "mamba_scan"]
