"""jit'd wrapper for flash attention: Pallas on TPU (or interpret mode for
validation); the memory-bounded chunked-jnp path otherwise."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "use_kernel", "interpret", "bq", "bk")
)
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_kernel: bool = False, interpret: bool = False,
                    bq: int = 128, bk: int = 128):
    if use_kernel or jax.default_backend() == "tpu":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, bq=bq, bk=bk,
            interpret=interpret or jax.default_backend() != "tpu",
        )
    from repro.models import nn

    return nn.attention(q, k, v, causal=causal, window=window)
