"""jit'd wrapper for flash attention: Pallas on TPU (or interpret mode for
validation); the memory-bounded chunked-jnp path otherwise.

Also the kernel's trace-capture shim (:func:`trace_geometry`): the grid /
BlockSpec index-map math of ``flash_attention_pallas`` mirrored into a
jax-free :class:`~repro.capture.geometry.KernelGeometry` so the DS
simulator can observe the kernel's block-level HBM stream without a TPU
(DESIGN.md §2.8; drift against the kernel is locked by
tests/test_capture.py)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import (
    DEFAULT_BK,
    DEFAULT_BQ,
    flash_attention_pallas,
)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "use_kernel", "interpret", "bq", "bk")
)
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_kernel: bool = False, interpret: bool = False,
                    bq: int = 128, bk: int = 128):
    if use_kernel or jax.default_backend() == "tpu":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, bq=bq, bk=bk,
            interpret=interpret or jax.default_backend() != "tpu",
        )
    from repro.models import nn

    return nn.attention(q, k, v, causal=causal, window=window)


def trace_geometry(*, b: int, sq: int, skv: int, h: int, kvh: int, d: int,
                   bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                   variant: str = "prefill"):
    """Capture shim: the exact grid + index maps of
    ``flash_attention_pallas`` for a (B, Sq, H, D) x (B, Skv, KVH, D)
    launch — grid (B*H, Sq/BQ, Skv/BK), KV axis innermost, Q/O parked
    across the KV loop, K/V shared across GQA head groups."""
    from repro.capture.geometry import KernelGeometry, Operand

    assert h % kvh == 0
    g = h // kvh
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    grid = (b * h, sq // bq, skv // bk)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return ((bh // h) * kvh + (bh % h) // g, ki, 0)

    # per grid step: QK^T scores (2*bq*bk*d) + PV gather (2*bq*bk*d)
    flops = 4.0 * bq * bk * d
    return KernelGeometry(
        kernel="flash_attention", variant=variant, grid=grid,
        operands=(
            Operand("q", (b * h, sq, d), (1, bq, d), q_map),
            Operand("k", (b * kvh, skv, d), (1, bk, d), kv_map),
            Operand("v", (b * kvh, skv, d), (1, bk, d), kv_map),
            Operand("o", (b * h, sq, d), (1, bq, d), q_map, is_output=True),
        ),
        flops_per_step=flops,
    )
