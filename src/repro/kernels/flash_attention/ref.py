"""Naive full-materialization attention oracle (causal / sliding-window /
GQA) — the ground truth for the Pallas flash kernel."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, Sq, H, D); k, v: (B, Skv, KVH, D).  f32 softmax."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    scores /= jnp.sqrt(jnp.asarray(d, jnp.float32))
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
