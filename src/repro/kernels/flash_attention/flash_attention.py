"""Pallas TPU flash attention: online-softmax tiling with causal and
sliding-window masking and native GQA (kv-head sharing — no materialized
repeat, unlike the XLA path).

Tiling: grid = (B * H, Sq / BQ, Skv / BK), the KV axis innermost and
*sequential* so the running max / sum / accumulator live in VMEM scratch
across KV steps (TPU grids execute minor-to-major sequentially).  Each step
does a (BQ, D) x (D, BK) MXU matmul for scores and a (BQ, BK) x (BK, D) MXU
matmul for the value gather; masks come from iota comparisons on the VPU.

VMEM budget per step (BQ=BK=128, D<=256, f32):
  q (128*256*4 = 128 KiB) + k,v (2x128 KiB) + acc (128 KiB) + scores (64 KiB)
  << 16 MiB v5e VMEM, leaving room for double-buffered HBM->VMEM prefetch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, scale: float, bq: int, bk: int,
                  n_kv_blocks: int):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (BQ, D)
    k = k_ref[0].astype(jnp.float32)  # (BK, D)
    v = v_ref[0].astype(jnp.float32)  # (BK, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (BQ, BK)

    qpos = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (BQ, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)  # (BQ, BK)
    # fully-masked rows: m_cur == NEG_INF -> p == exp(0) == 1; zero them
    p = jnp.where(m_cur > NEG_INF / 2, p, 0.0)
    alpha = jnp.where(m_cur > NEG_INF / 2, alpha, 0.0)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_cur

    @pl.when(kv_idx == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, KVH, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0
    g = h // kvh
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    scale = 1.0 / math.sqrt(d)

    # (B, S, H, D) -> (B*H, S, D); kv head for flat head j is j // g
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)

    n_kv = skv // bk
    grid = (b * h, sq // bq, n_kv)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return ((bh // h) * kvh + (bh % h) // g, ki, 0)

    from jax.experimental.pallas import tpu as pltpu

    kern = functools.partial(
        _flash_kernel, causal=causal, window=window, scale=scale,
        bq=bq, bk=bk, n_kv_blocks=n_kv,
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max m
            pltpu.VMEM((bq, 1), jnp.float32),  # running denom l
            pltpu.VMEM((bq, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
