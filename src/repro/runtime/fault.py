"""Fault tolerance + straggler mitigation for 1000+-node operation.

Components (single-process-testable; the same state machines drive a real
multi-host deployment through jax.distributed + the launcher):

  HeartbeatMonitor  — per-host liveness from periodic beats; marks hosts
                      SUSPECT after ``suspect_after`` missed intervals and
                      DEAD after ``dead_after`` (failure detector φ-style,
                      simplified to fixed windows).
  StragglerPolicy   — per-step host timing ring buffer; escalation ladder:
                      observe -> rebalance (shrink slow host's data shard) ->
                      exclude (drop + reweight) -> evict (trigger elastic
                      restart).  Hysteresis prevents flapping.
  RunSupervisor     — ties them together with the CheckpointManager: on a
                      DEAD host or an EVICT decision it requests an elastic
                      restart from the latest checkpoint with the surviving
                      host set (runtime/elastic.py computes the new mesh).

Tests inject synthetic beats/timings (tests/test_runtime.py).
"""
from __future__ import annotations

import enum
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple


class HostState(enum.Enum):
    ALIVE = "alive"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclass
class HeartbeatMonitor:
    interval_s: float = 10.0
    suspect_after: int = 2  # missed intervals
    dead_after: int = 6
    last_beat: Dict[int, float] = field(default_factory=dict)

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self.last_beat[host] = time.time() if now is None else now

    def state(self, host: int, now: Optional[float] = None) -> HostState:
        now = time.time() if now is None else now
        t = self.last_beat.get(host)
        if t is None:
            return HostState.DEAD
        missed = (now - t) / self.interval_s
        if missed >= self.dead_after:
            return HostState.DEAD
        if missed >= self.suspect_after:
            return HostState.SUSPECT
        return HostState.ALIVE

    def dead_hosts(self, hosts: List[int], now: Optional[float] = None) -> List[int]:
        return [h for h in hosts if self.state(h, now) == HostState.DEAD]


class Action(enum.Enum):
    NONE = "none"
    REBALANCE = "rebalance"  # shrink the slow host's data shard
    EXCLUDE = "exclude"  # drop its gradient contribution + reweight
    EVICT = "evict"  # remove from the job -> elastic restart


@dataclass
class StragglerPolicy:
    window: int = 20  # steps of history per host
    slow_ratio: float = 1.3  # step_time / median above which a host is slow
    rebalance_after: int = 5  # consecutive slow steps before acting
    exclude_after: int = 15
    evict_after: int = 40
    _hist: Dict[int, deque] = field(default_factory=lambda: defaultdict(lambda: deque(maxlen=64)))
    _slow_streak: Dict[int, int] = field(default_factory=lambda: defaultdict(int))

    def observe_step(self, times: Dict[int, float]) -> Dict[int, Action]:
        """times: host -> step wall time.  Returns per-host actions."""
        if not times:
            return {}
        med = sorted(times.values())[len(times) // 2]
        out: Dict[int, Action] = {}
        for h, t in times.items():
            self._hist[h].append(t)
            if med > 0 and t / med >= self.slow_ratio:
                self._slow_streak[h] += 1
            else:
                self._slow_streak[h] = 0
            s = self._slow_streak[h]
            if s >= self.evict_after:
                out[h] = Action.EVICT
            elif s >= self.exclude_after:
                out[h] = Action.EXCLUDE
            elif s >= self.rebalance_after:
                out[h] = Action.REBALANCE
            else:
                out[h] = Action.NONE
        return out


@dataclass
class RunSupervisor:
    hosts: List[int]
    monitor: HeartbeatMonitor = field(default_factory=HeartbeatMonitor)
    policy: StragglerPolicy = field(default_factory=StragglerPolicy)
    on_elastic_restart: Optional[Callable[[List[int]], None]] = None
    excluded: Set[int] = field(default_factory=set)
    events: List[Tuple[str, int]] = field(default_factory=list)

    def tick(self, step_times: Dict[int, float], now: Optional[float] = None) -> Optional[List[int]]:
        """One supervision round.  Returns the new host list if an elastic
        restart is required, else None."""
        dead = set(self.monitor.dead_hosts(self.hosts, now))
        for h in dead:
            self.events.append(("dead", h))
        actions = self.policy.observe_step(
            {h: t for h, t in step_times.items() if h not in dead}
        )
        evict = {h for h, a in actions.items() if a == Action.EVICT}
        for h, a in actions.items():
            if a == Action.EXCLUDE and h not in self.excluded:
                self.excluded.add(h)
                self.events.append(("exclude", h))
            elif a == Action.REBALANCE:
                self.events.append(("rebalance", h))
        removed = dead | evict
        if removed:
            survivors = [h for h in self.hosts if h not in removed]
            self.hosts = survivors
            self.excluded -= removed
            for h in evict:
                self.events.append(("evict", h))
            if self.on_elastic_restart:
                self.on_elastic_restart(survivors)
            return survivors
        return None
