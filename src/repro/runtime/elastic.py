"""Elastic mesh reconfiguration: map a surviving host set onto a new
(pod, data, model) mesh and re-shard the run state.

Policy: TP ("model") degree is pinned (it matches the model's sharded
matrix layouts and intra-pod ICI); elasticity happens on the DP axes —
the largest data degree that divides both the surviving chip count and the
global batch is chosen, spare hosts idle as hot standbys.  Checkpoints are
mesh-agnostic (global arrays), so restore-with-new-shardings IS the
re-shard (checkpoint/ckpt.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class MeshPlan:
    pods: int
    data: int
    model: int
    used_chips: int
    spare_chips: int

    @property
    def shape(self) -> Tuple[int, ...]:
        return (self.pods, self.data, self.model) if self.pods > 1 else (self.data, self.model)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.pods > 1 else ("data", "model")


def plan_mesh(
    n_chips: int, *, model_degree: int = 16, global_batch: int = 256,
    chips_per_pod: int = 256,
) -> MeshPlan:
    """Largest viable (pod, data, model) layout for the surviving chips."""
    if n_chips < model_degree:
        raise ValueError(f"need >= {model_degree} chips for TP, have {n_chips}")
    pods = max(1, n_chips // chips_per_pod)
    while pods > 1 and n_chips // pods < model_degree:
        pods -= 1
    per_pod = n_chips // pods
    data = per_pod // model_degree
    # data degree must divide the global batch (whole sequences per shard)
    while data > 1 and global_batch % (data * pods):
        data -= 1
    used = pods * data * model_degree
    return MeshPlan(pods, data, model_degree, used, n_chips - used)


def replan_after_failure(
    old: MeshPlan, lost_chips: int, global_batch: int = 256
) -> MeshPlan:
    return plan_mesh(
        old.used_chips + old.spare_chips - lost_chips,
        model_degree=old.model,
        global_batch=global_batch,
        chips_per_pod=max(old.data * old.model, 1),
    )
