"""Logical-axis -> mesh-axis resolution (MaxText-style).

Model code annotates parameters (via ``ParamSpec.axes``) and activations (via
``nn.logical_constraint``) with *logical* names; this module maps them onto
the physical mesh:

  TP axis ("model"):  vocab, mlp, heads, kv_heads, experts, ssm_inner, ssm_heads
  FSDP axis ("data"): embed (the d_model dim of every weight matrix)
  DP axes:            act_batch -> ("pod", "data") / ("data",)

For *jit inputs* (params, optimizer state, caches, batches) a mesh axis is
dropped from the spec when the dimension is not divisible by the axis size
(uneven input shardings are where GSPMD padding hurts; constraints inside the
program may still pad).  This keeps e.g. a kv_heads=8 cache valid on a
model=16 mesh by replicating that dim.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import nn
from repro.models.nn import ParamSpec

MeshAxes = Any  # str | tuple[str, ...] | None


def base_rules(mesh: Mesh, *, fsdp: bool = True, zero_weights_on_pod: bool = False,
               cache_shard: str = "seq") -> Dict[str, MeshAxes]:
    axes = mesh.axis_names
    dp_axes = tuple(a for a in ("pod", "data") if a in axes)
    fsdp_axes: MeshAxes = None
    if fsdp:
        fsdp_axes = dp_axes if zero_weights_on_pod else tuple(a for a in dp_axes if a != "pod")
        if len(fsdp_axes) == 1:
            fsdp_axes = fsdp_axes[0]
        elif not fsdp_axes:
            fsdp_axes = None
    model = "model" if "model" in axes else None
    return {
        "vocab": model,
        "mlp": model,
        "heads": model,
        "kv_heads": model,
        "experts": model,
        "ssm_inner": model,
        "ssm_heads": model,
        "embed": fsdp_axes,
        # decode caches take the TP axis on exactly one dim (§Perf A1):
        #   seq — flash-decoding split-KV (baseline)
        #   dh  — head_dim split: cache writes stay local, scores partial-sum
        "kv_seq": model if cache_shard == "seq" else None,
        "kv_dh": model if cache_shard == "dh" else None,
        "lora": None,
        "lora_cache": None,
        "experts_router": None,
        "layers": None,
        "act_batch": dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None),
    }


def _axis_size(mesh: Mesh, entry: MeshAxes) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    return int(np.prod([mesh.shape[a] for a in entry]))


def spec_for(
    mesh: Mesh, rules: Dict[str, MeshAxes], axes: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
) -> P:
    """PartitionSpec for one tensor, dropping non-divisible mesh axes."""
    entries = []
    for dim, name in zip(shape, axes):
        entry = rules.get(name) if name else None
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None  # replicate: uneven jit-input shardings disallowed
        entries.append(entry)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def sharding_for_specs(mesh: Mesh, rules: Dict[str, MeshAxes], specs: Any) -> Any:
    """ParamSpec pytree -> NamedSharding pytree (same structure)."""

    def one(s: ParamSpec) -> NamedSharding:
        return NamedSharding(mesh, spec_for(mesh, rules, s.axes, s.shape))

    return nn.spec_tree_map(one, specs)


def batch_sharding(mesh: Mesh, rules: Dict[str, MeshAxes], batch_specs: Any) -> Any:
    """Shard every array-like input on its leading (batch) dim; scalars replicated."""
    dp = rules.get("act_batch")

    def one(x):
        shape = x.shape
        if not shape:
            return NamedSharding(mesh, P())
        entry = dp
        if entry is not None and shape[0] % _axis_size(mesh, entry) != 0:
            entry = None
        return NamedSharding(mesh, P(entry))

    return jax.tree.map(one, batch_specs)


def cache_sharding(mesh: Mesh, rules: Dict[str, MeshAxes], cache_specs: Any) -> Any:
    return sharding_for_specs(mesh, rules, cache_specs)


def activate(mesh: Mesh, rules: Dict[str, MeshAxes]) -> None:
    """Install rules so nn.logical_constraint resolves inside jit bodies."""
    nn.set_logical_rules(mesh, rules)


def deactivate() -> None:
    nn.clear_logical_rules()
