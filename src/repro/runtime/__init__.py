from repro.runtime import sharding

__all__ = ["sharding"]
