"""LR schedules: cosine and WSD (warmup-stable-decay, minicpm's schedule)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, peak_lr: float, total_steps: int, warmup_steps: int = 100,
           min_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    frac = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup_steps, warm, cos)


def wsd(step, *, peak_lr: float, total_steps: int, warmup_steps: int = 100,
        decay_frac: float = 0.1, min_ratio: float = 0.01):
    """Warmup -> stable (constant) -> exponential-ish linear decay tail."""
    step = jnp.asarray(step, jnp.float32)
    decay_steps = jnp.maximum(total_steps * decay_frac, 1)
    decay_start = total_steps - decay_steps
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    tail_frac = jnp.clip((step - decay_start) / decay_steps, 0, 1)
    tail = peak_lr * (min_ratio ** tail_frac)  # exponential decay tail
    lr = jnp.where(step < warmup_steps, warm, jnp.where(step < decay_start, peak_lr, tail))
    return lr


def make(name: str, **kw):
    fn = {"cosine": cosine, "wsd": wsd}[name]
    return lambda step: fn(step, **kw)
