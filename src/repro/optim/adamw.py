"""AdamW with global-norm clipping.  Optimizer state mirrors the parameter
tree (so it inherits parameter sharding); the DaeMon-integrated ZeRO-1 path
(core/movement) shards this state over the DP axes and re-gathers params with
compressed page-granularity collectives.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # ()
    m: Any  # like params
    v: Any  # like params


def init(params: Any) -> AdamWState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros, jax.tree.map(jnp.zeros_like, params))


def init_abstract(params: Any) -> AdamWState:
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), z, z)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
