"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16e top-4, fine-grained.  [hf:databricks/dbrx-base; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    source="[hf:databricks/dbrx-base; unverified]",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10_752,
    vocab_size=100_352,
    attn_kind="full",
    num_experts=16,
    num_shared_experts=0,
    top_k=4,
    moe_d_ff=10_752,
    first_dense_layers=0,
    rope_theta=500_000.0,
    moe_group_size=8_192,  # §Perf C1: fewer group-scan trips; dispatch buffer
    #                        (16, 2560, 6144) bf16 = 0.5 GB stays remat-able
)
