"""Model/arch configuration system.

Every assigned architecture is a ``ModelConfig`` instance in its own module
(``src/repro/configs/<id>.py``); the registry in ``__init__`` exposes them by
``--arch <id>``.  ``reduced()`` derives the small same-family config used by
the per-arch CPU smoke tests; the full configs are exercised only through the
dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple


@dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell (seq_len x global_batch)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned LM shape cells.  ``decode_*``/``long_*`` lower
# ``serve_step`` (one new token against a KV cache of seq_len), not
# ``train_step``.
SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)

SHAPES = {c.name: c for c in SHAPE_CELLS}


@dataclass(frozen=True)
class ModelConfig:
    # identity -----------------------------------------------------------
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""  # provenance note "[arXiv:...; tier]"

    # trunk --------------------------------------------------------------
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # attention ----------------------------------------------------------
    attn_kind: str = "full"  # full | swa | mla | none
    window: int = 0  # sliding-window size when attn_kind == "swa"
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    pos_embed: str = "rope"  # rope | sinusoidal (whisper)

    # MLA (deepseek) -----------------------------------------------------
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # leading layers that stay dense
    capacity_factor: float = 1.25
    moe_group_size: int = 4_096  # tokens per dispatch group (memory bound)

    # SSM (mamba1/2) -----------------------------------------------------
    ssm_version: int = 0  # 0 = none, 1 = mamba1, 2 = mamba2
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64  # mamba2 head size P
    dt_rank: int = 0  # mamba1 dt projection rank
    ssm_algo: str = "scan"  # mamba2 seq mixer: "scan" (elementwise assoc-scan)
    #                         or "ssd" (matmul/SSD form — MXU-friendly, §Perf B)

    # hybrid (zamba2): one *shared* attention+MLP block applied every
    # ``attn_every`` SSM blocks, with small per-invocation LoRA adapters.
    attn_every: int = 0
    shared_lora_rank: int = 0

    # enc-dec (whisper) ---------------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0

    # modality frontends are STUBS per assignment: input_specs() provides
    # precomputed patch/frame embeddings of width d_model.
    frontend: str = ""  # "" | "vit_stub" | "audio_stub"
    num_prefix_tokens: int = 0  # vision tokens prepended to the text stream

    # numerics / training --------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    schedule: str = "cosine"  # "wsd" for minicpm
    remat: str = "dots"  # nothing | dots | full
    attn_chunk: int = 1_024  # query-chunked attention block (memory bound)

    # ----------------------------------------------------------------- api
    @property
    def is_encdec(self) -> bool:
        return self.family == "audio" and self.enc_layers > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_version == 2 else 0

    def supports_long_context(self) -> bool:
        """Whether the ``long_500k`` cell applies (sub-quadratic attention)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn_kind == "swa"

    def live_cells(self) -> Tuple[ShapeCell, ...]:
        """The shape cells that are live for this arch (spec-mandated skips)."""
        cells = []
        for c in SHAPE_CELLS:
            if c.name == "long_500k" and not self.supports_long_context():
                continue
            cells.append(c)
        return tuple(cells)

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""

        def shrink(v, lo, div):
            return max(lo, v // div) if v else 0

        kw = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2) if self.num_layers else 0,
            d_model=64,
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=16 if self.num_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 256),
            window=min(self.window, 16) if self.window else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_nope_dim=16 if self.qk_nope_dim else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            num_experts=min(self.num_experts, 4),
            num_shared_experts=min(self.num_shared_experts, 1),
            top_k=min(self.top_k, 2),
            moe_d_ff=32 if self.moe_d_ff else 0,
            moe_group_size=64,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_version == 2 else self.ssm_head_dim,
            dt_rank=shrink(self.dt_rank, 4, 64),
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            shared_lora_rank=min(self.shared_lora_rank, 4),
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            dec_layers=min(self.dec_layers, 2) if self.dec_layers else 0,
            num_prefix_tokens=min(self.num_prefix_tokens, 4),
            attn_chunk=32,
        )
        # keep kv heads dividing heads
        if kw["num_heads"]:
            while kw["num_heads"] % max(kw["num_kv_heads"], 1):
                kw["num_kv_heads"] += 1
        return replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count N (for MODEL_FLOPS = 6*N*D)."""
        from repro.models import model as _m

        return _m.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import model as _m

        return _m.param_count(self, active_only=True)


def validate(cfg: ModelConfig) -> None:
    if cfg.num_heads and cfg.num_kv_heads:
        assert cfg.num_heads % cfg.num_kv_heads == 0, (
            f"{cfg.name}: heads {cfg.num_heads} % kv {cfg.num_kv_heads}"
        )
    if cfg.family == "moe":
        assert cfg.num_experts > 0 and cfg.top_k > 0
    if cfg.family in ("ssm", "hybrid"):
        assert cfg.ssm_version in (1, 2)
    if cfg.attn_kind == "swa":
        assert cfg.window > 0
    if cfg.attn_kind == "mla":
        assert cfg.kv_lora_rank > 0
