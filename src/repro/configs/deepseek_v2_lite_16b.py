"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6 — MLA kv_lora=512, 2 shared + routed experts.
[arXiv:2405.04434; hf]

Notes: the assignment's primary line specifies 64 routed experts top-6 with
expert d_ff=1408 (the "160 routed" aside describes full DeepSeek-V2; we follow
the primary line).  Layer 0 is dense (d_ff=10944, per the HF config); layers
1..26 are MoE with 2 shared experts.  MLA caches the 512-dim compressed c_kv +
64-dim decoupled rope key per token instead of full K/V — the arch's native
"KV compression", synergistic with DaeMon link compression (see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="[arXiv:2405.04434; hf]",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=192,  # qk_nope(128) + qk_rope(64)
    d_ff=10_944,  # dense first layer
    vocab_size=102_400,
    attn_kind="mla",
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=10_000.0,
)
