"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + (Llama-3-70B-class) LM backbone.
[arXiv:2404.16821; unverified]

Per the assignment, the ViT frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (num_prefix_tokens x d_model) that are prepended
to the text token stream; only the LM backbone is modeled.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    source="[arXiv:2404.16821; unverified]",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=128_256,
    attn_kind="full",
    rope_theta=500_000.0,
    frontend="vit_stub",
    num_prefix_tokens=256,  # vision tokens per sample
)
