"""h2o-danube-1.8b [dense] — 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]

SWA makes attention sub-quadratic in context length, so this arch runs the
``long_500k`` cell (decode KV cache is bounded by the window).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    source="[arXiv:2401.16818; hf]",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32_000,
    attn_kind="swa",
    window=4_096,  # mistral-style sliding window
    rope_theta=10_000.0,
)
