"""whisper-base [audio] — 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865 —
encoder-decoder with conv frontend (STUB).  [arXiv:2212.04356; unverified]

Per the assignment the conv frontend is a stub: ``input_specs()`` provides
precomputed frame embeddings (seq_len x d_model) to the encoder.  The decoder
has self-attention (causal, cached) + cross-attention to encoder states
(cached at prefill).  Sinusoidal positions, MHA, no rope.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    source="[arXiv:2212.04356; unverified]",
    num_layers=6,  # == enc_layers == dec_layers
    enc_layers=6,
    dec_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51_865,
    attn_kind="full",
    pos_embed="sinusoidal",
    frontend="audio_stub",
    tie_embeddings=True,
)
