"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16 — mamba1 architecture.  [arXiv:2410.05355; unverified]

Attention-free: no KV cache; decode state is the (d_inner, d_state) SSM
state + conv tail per layer, so the ``long_500k`` cell runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    source="[arXiv:2410.05355; unverified]",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,  # attn-free mamba1 block has no separate MLP
    vocab_size=65_024,
    attn_kind="none",
    ssm_version=1,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,  # d_inner = 8192
    dt_rank=256,  # d_model / 16
    tie_embeddings=False,
)
