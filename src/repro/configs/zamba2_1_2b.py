"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + one SHARED attention+MLP block
applied every 6 SSM blocks with per-invocation LoRA adapters.
[arXiv:2411.15242; hf]

Hybrid (mostly-SSM) ⇒ ``long_500k`` runs; the shared attention invocations
use the full cache at decode (cheap: a handful of invocations).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="[arXiv:2411.15242; hf]",
    num_layers=38,  # mamba2 blocks
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,  # shared block is MHA
    head_dim=64,
    d_ff=8192,  # shared block MLP
    vocab_size=32_000,
    attn_kind="full",
    ssm_version=2,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,  # d_inner = 4096
    ssm_head_dim=64,  # 64 mamba2 heads
    attn_every=6,  # shared block at SSM blocks 0,6,12,18,24,30,36
    shared_lora_rank=64,
    rope_theta=10_000.0,
    ssm_algo="ssd",  # §Perf B1: 6.4x lower memory term than the elementwise
    #                  scan (numerically identical); baseline via --ssm-algo scan
)
