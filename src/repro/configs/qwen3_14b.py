"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm + GQA.  [hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    source="[hf:Qwen/Qwen3-8B; hf]",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17_408,
    vocab_size=151_936,
    attn_kind="full",
    qk_norm=True,  # per-head RMSNorm on q and k (qwen3)
    rope_theta=1_000_000.0,
)
