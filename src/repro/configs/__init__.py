"""Config registry — ``--arch <id>`` resolution.

>>> from repro.configs import get_config, ARCHS
>>> cfg = get_config("qwen3-14b")
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeCell, SHAPE_CELLS, SHAPES, validate

from repro.configs import (
    minicpm_2b,
    h2o_danube_1_8b,
    stablelm_12b,
    qwen3_14b,
    falcon_mamba_7b,
    deepseek_v2_lite_16b,
    dbrx_132b,
    zamba2_1_2b,
    internvl2_76b,
    whisper_base,
)

_MODULES = (
    minicpm_2b,
    h2o_danube_1_8b,
    stablelm_12b,
    qwen3_14b,
    falcon_mamba_7b,
    deepseek_v2_lite_16b,
    dbrx_132b,
    zamba2_1_2b,
    internvl2_76b,
    whisper_base,
)

REGISTRY = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ARCHS = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {', '.join(ARCHS)}")
    cfg = REGISTRY[name]
    validate(cfg)
    return cfg


__all__ = [
    "ModelConfig",
    "ShapeCell",
    "SHAPE_CELLS",
    "SHAPES",
    "REGISTRY",
    "ARCHS",
    "get_config",
    "validate",
]
