"""minicpm-2b [dense] — 40L d_model=2304 36H (GQA kv=36) d_ff=5760
vocab=122753, WSD schedule, llama-like.  [arXiv:2404.06395; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    source="[arXiv:2404.06395; hf]",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,  # MHA (kv == heads)
    head_dim=64,
    d_ff=5760,
    vocab_size=122_753,
    attn_kind="full",
    rope_theta=10_000.0,
    schedule="wsd",  # warmup-stable-decay, per the paper
    tie_embeddings=True,  # minicpm ties input/output embeddings
)
