"""KernelTraceRecorder: derive a DS-simulator trace from kernel geometry
(DESIGN.md §2.8).

The recorder walks the grid in TPU execution order (last axis innermost,
sequential) and replays the Pallas pipelining contract: an operand's block
moves HBM<->VMEM **only when its index map changes value between steps** —
a flash-attention Q tile parked across the whole KV loop is fetched once,
while K/V stream every step; an output block is written back when the grid
moves off it (and at grid end).  Each movement is emitted at line (64 B)
granularity over the block's byte extent, so the captured stream has the
signature shape of real tiled kernels: dense spatially-local runs inside a
tile, abrupt inter-tile jumps between operand regions.

Compute gaps come from the roofline model (launch/roofline.py): a trace's
``gaps`` are *compute* cycles between accesses (the simulator prices the
memory side itself), so one grid step's MXU/VPU work — ``flops_per_step /
PEAK_FLOPS`` seconds at the simulator's 3 GHz nominal clock — lands as a
lump on the step's first access, and the accesses inside a tile burst run
back-to-back (gap 1).  The captured stream is therefore bursty by
construction: dense line runs per tile, a roofline compute lump between
tiles.  The walk is fully deterministic — no RNG anywhere — so the same
geometry always yields a bit-identical trace (locked by
tests/test_capture.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from repro.capture.geometry import KernelGeometry, assign_regions, block_line_addrs
from repro.launch.roofline import PEAK_FLOPS

CLOCK_HZ = 3e9  # simulator cycles are a 3 GHz nominal clock (SimConfig)

Trace = Tuple[np.ndarray, np.ndarray, np.ndarray]


@dataclass
class CaptureResult:
    """A captured kernel launch: the (gaps, addrs, writes) trace plus the
    per-operand movement accounting the compressibility measurement weighs
    payload samples by (compress.py)."""

    geom: KernelGeometry
    gaps: np.ndarray
    addrs: np.ndarray
    writes: np.ndarray
    regions: Dict[str, int]  # operand -> base byte address
    moved_bytes: Dict[str, int]  # operand -> total bytes moved over HBM

    @property
    def trace(self) -> Trace:
        return self.gaps, self.addrs, self.writes

    @property
    def n_accesses(self) -> int:
        return len(self.addrs)

    @property
    def footprint(self) -> int:
        return int(self.addrs.max()) + 64 if len(self.addrs) else 0


class KernelTraceRecorder:
    """Walk one :class:`KernelGeometry` and record its block-level trace."""

    def __init__(self, geom: KernelGeometry):
        self.geom = geom
        self.regions = assign_regions(geom)

    def record(self) -> CaptureResult:
        geom = self.geom
        chunks_addr: list = []
        chunks_write: list = []
        step_access_counts: list = []
        step_cycles: list = []
        last_idx: Dict[str, Tuple[int, ...]] = {}
        moved: Dict[str, int] = {op.name: 0 for op in geom.operands}

        def move(op, block_idx, write: bool):
            lines = block_line_addrs(op, self.regions[op.name], block_idx)
            chunks_addr.append(lines)
            chunks_write.append(np.full(len(lines), write, bool))
            moved[op.name] += op.block_nbytes
            return len(lines)

        step_compute = geom.flops_per_step / PEAK_FLOPS * CLOCK_HZ
        for step in geom.steps():
            n_acc = 0
            for op in geom.operands:
                idx = tuple(int(i) for i in op.index_map(*step))
                prev = last_idx.get(op.name)
                if prev == idx:
                    continue  # block parked in VMEM: no HBM movement
                if op.is_output:
                    # write back the block we are moving OFF of; the new
                    # block needs no fetch (outputs are write-only here)
                    if prev is not None:
                        n_acc += move(op, prev, write=True)
                else:
                    n_acc += move(op, idx, write=False)
                last_idx[op.name] = idx
            step_access_counts.append(n_acc)
            step_cycles.append(step_compute)
        # final writeback of every output's resident block (no compute left)
        n_final = 0
        for op in geom.operands:
            if op.is_output and op.name in last_idx:
                n_final += move(op, last_idx[op.name], write=True)
        if n_final:
            step_access_counts.append(n_final)
            step_cycles.append(0.0)

        addrs = np.concatenate(chunks_addr) if chunks_addr else np.zeros(0, np.int64)
        writes = np.concatenate(chunks_write) if chunks_write else np.zeros(0, bool)
        # bursty gap layout: the step's compute lump on its first access,
        # back-to-back (gap 1) inside the tile burst; steps that moved
        # nothing carry their compute into the next burst's lump
        gaps = np.ones(len(addrs), np.int64)
        pos = 0
        carry = 0.0
        for n_acc, cyc in zip(step_access_counts, step_cycles):
            if n_acc == 0:
                carry += cyc
                continue
            gaps[pos] = max(1, int(round(cyc + carry)))
            carry = 0.0
            pos += n_acc
        return CaptureResult(geom=geom, gaps=gaps, addrs=addrs, writes=writes,
                             regions=dict(self.regions), moved_bytes=moved)
