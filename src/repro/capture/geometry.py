"""Kernel tiling geometry for trace capture (DESIGN.md §2.8).

A Pallas kernel's HBM traffic is fully determined by its *tiling geometry*:
the grid, and per operand a block shape plus the BlockSpec index map that
places a block for every grid step.  This module gives that geometry a
first-class, jax-free representation so the DS simulator can observe the
kernels' block-level memory streams without a TPU (or even a jax import):
each kernel's ``ops.py`` carries a lightweight tracing shim that mirrors
its own grid / index-map math into a :class:`KernelGeometry`, and the
:class:`~repro.capture.recorder.KernelTraceRecorder` walks it.

Operands are laid out in **disjoint, page-aligned address regions** (one
guard page apart) so the replayed trace preserves which tensor a line
belongs to — inter-operand jumps in the captured stream are real region
switches, never aliasing artifacts (locked by tests/test_capture.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import numpy as np

PAGE_BYTES = 4096  # region alignment; matches SimConfig.page_bytes default
LINE_BYTES = 64

# payload models for measured compressibility (compress.py): what byte
# distribution a region holds when the kernel runs on representative data
PAYLOADS = ("f32_dense", "f32_act_sparse", "f32_pos", "f32_scales",
            "int8_quant")


@dataclass(frozen=True)
class Operand:
    """One kernel operand: an HBM array tiled into VMEM blocks.

    ``index_map`` is the BlockSpec index map — grid indices -> block
    indices — copied from the kernel's own ``pallas_call`` (the shim in the
    kernel's ``ops.py`` is the authoritative mirror; drift against the
    kernel constants is locked by tests).  ``payload`` names the
    representative byte distribution of the region (see PAYLOADS).
    """

    name: str
    shape: Tuple[int, ...]  # full array shape
    block: Tuple[int, ...]  # VMEM block shape (same rank)
    index_map: Callable[..., Tuple[int, ...]]
    elem_bytes: int = 4
    is_output: bool = False
    payload: str = "f32_dense"

    def __post_init__(self):
        if len(self.shape) != len(self.block):
            raise ValueError(
                f"operand {self.name!r}: shape {self.shape} and block "
                f"{self.block} must have equal rank")
        for s, b in zip(self.shape, self.block):
            if s % b:
                raise ValueError(
                    f"operand {self.name!r}: block {self.block} must tile "
                    f"shape {self.shape} exactly")
        if self.payload not in PAYLOADS:
            raise ValueError(
                f"operand {self.name!r}: unknown payload {self.payload!r} "
                f"(choices: {PAYLOADS})")

    @property
    def nbytes(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n * self.elem_bytes

    @property
    def block_nbytes(self) -> int:
        n = 1
        for b in self.block:
            n *= b
        return n * self.elem_bytes


@dataclass(frozen=True)
class KernelGeometry:
    """Grid + operands of one kernel launch — everything the recorder needs
    to derive the launch's block-level HBM access stream.

    ``flops_per_step`` feeds the roofline gap model (recorder.py): the
    compute work one grid step overlaps with its tile movement.  The grid
    executes minor-to-major with the **last axis innermost and sequential**
    (TPU semantics — this ordering is what makes carried VMEM state and
    block reuse across steps meaningful).
    """

    kernel: str  # source kernel, e.g. "flash_attention"
    variant: str  # e.g. "prefill"
    grid: Tuple[int, ...]
    operands: Tuple[Operand, ...]
    flops_per_step: float = 0.0

    def __post_init__(self):
        names = [op.name for op in self.operands]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate operand names: {names}")

    @property
    def n_steps(self) -> int:
        n = 1
        for g in self.grid:
            n *= g
        return n

    def steps(self):
        """Grid steps in execution order (last axis fastest)."""
        return np.ndindex(*self.grid)


def assign_regions(geom: KernelGeometry) -> Dict[str, int]:
    """Operand name -> base byte address.  Regions are page-aligned, sized
    to the operand, laid out in declaration order with one guard page
    between — disjoint by construction."""
    bases: Dict[str, int] = {}
    cursor = 0
    for op in geom.operands:
        bases[op.name] = cursor
        size = -(-op.nbytes // PAGE_BYTES) * PAGE_BYTES  # round up
        cursor += size + PAGE_BYTES  # guard page
    return bases


def block_line_addrs(op: Operand, base: int,
                     block_idx: Tuple[int, ...]) -> np.ndarray:
    """Line-granular byte addresses touched when ``block_idx`` of ``op``
    moves between HBM and VMEM.

    A block is contiguous along the minor (last) axis only; every other
    block axis contributes strided rows — so a (TR, TC) tile of an (R, C)
    array with TC < C yields TR separate runs, which is exactly the
    intra-tile-dense / inter-run-strided shape real tiled kernels put on
    the memory system.
    """
    rank = len(op.shape)
    # element strides (row-major)
    strides = [0] * rank
    acc = 1
    for i in range(rank - 1, -1, -1):
        strides[i] = acc
        acc *= op.shape[i]
    # start element offset of the block
    start = sum(block_idx[i] * op.block[i] * strides[i] for i in range(rank))
    # row starts: cartesian product over all block axes except the last
    row_elems = [np.arange(op.block[i]) * strides[i] for i in range(rank - 1)]
    rows = np.zeros(1, dtype=np.int64)
    for r in row_elems:
        rows = (rows[:, None] + r[None, :]).reshape(-1)
    run_bytes = op.block[-1] * op.elem_bytes
    run_starts = base + (start + rows) * op.elem_bytes
    # per-run line span from first to LAST touched byte: a run whose start
    # is not line-aligned can cross one more line boundary than its length
    # alone implies, so counts vary per run
    first = run_starts // LINE_BYTES
    last = (run_starts + run_bytes - 1) // LINE_BYTES
    counts = last - first + 1
    total = int(counts.sum())
    within = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
    lines = (np.repeat(first, counts) + within) * LINE_BYTES
    return lines.astype(np.int64)
