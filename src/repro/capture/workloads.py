"""The captured-kernel workload catalog (DESIGN.md §2.8).

Four representative launches of the repo's Pallas kernels, registered as
first-class DS-simulator workloads at ``repro.core.sim`` import time:

  fa_prefill  flash attention, 512-token GQA prefill — Q/O tiles parked
              across the streamed K/V loop (tile reuse + streaming)
  fa_decode   flash attention, batched single-token decode — tiny Q, the
              whole KV cache streamed per head (read-dominated scan)
  mamba_fwd   chunked selective scan — A parked per channel tile, B/C
              re-streamed for every channel tile, chunk I/O + y writeback
  bq_quant    per-block absmax int8 quantize — strided f32 tile reads,
              int8 payload + f32 scale writes (the compressible one)

Registration is import-cheap: geometry shims live in each kernel's
``ops.py`` (which imports jax), so the catalog defers that import to the
first actual use — building a trace or resolving the measured
compressibility — and caches the capture per process.  Replay semantics
(``seed`` rotates phase, ``n`` truncates/tiles, ``footprint`` is ignored —
the geometry is authoritative) are shared with ``.npz`` trace files via
:func:`repro.core.sim.trace.replay_slice`, and '+'-mix composition works
like any other registered workload.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, Tuple

from repro.capture.recorder import CaptureResult, KernelTraceRecorder


@dataclass(frozen=True)
class CapturedKernel:
    """Catalog entry: a named kernel launch whose geometry is built lazily
    (``module`` is imported — pulling in jax — only on first capture)."""

    name: str
    module: str  # the kernel's ops module carrying the trace_geometry shim
    config: Dict[str, object]  # kwargs for the shim
    description: str = ""

    def build_geometry(self):
        ops = importlib.import_module(self.module)
        return ops.trace_geometry(**self.config)


CAPTURED: Dict[str, CapturedKernel] = {}
_RESULTS: Dict[str, CaptureResult] = {}  # per-process capture cache


def _catalog(name: str, module: str, description: str, **config) -> None:
    CAPTURED[name] = CapturedKernel(name=name, module=module, config=config,
                                    description=description)


_FA = "repro.kernels.flash_attention.ops"
_MS = "repro.kernels.mamba_scan.ops"
_BQ = "repro.kernels.block_quant.ops"

_catalog("fa_prefill", _FA,
         "captured flash_attention prefill (GQA, Q parked over KV stream)",
         b=1, sq=512, skv=512, h=4, kvh=2, d=64, variant="prefill")
_catalog("fa_decode", _FA,
         "captured flash_attention decode (KV cache streamed per head)",
         b=4, sq=1, skv=512, h=2, kvh=1, d=128, bq=1, variant="decode")
_catalog("mamba_fwd", _MS,
         "captured mamba_scan forward (A parked, B/C re-streamed per tile)",
         b=1, s=1024, d=512, n=16, variant="fwd")
_catalog("bq_quant", _BQ,
         "captured block_quant quantize (strided f32 reads, int8+scale writes)",
         r=512, c=2048, variant="quant")


def capture(name: str) -> CaptureResult:
    """Run (or fetch the cached) capture for one catalog entry."""
    res = _RESULTS.get(name)
    if res is None:
        entry = CAPTURED.get(name)
        if entry is None:
            raise KeyError(
                f"unknown captured kernel {name!r}; catalog: "
                f"{', '.join(CAPTURED)}")
        res = _RESULTS[name] = KernelTraceRecorder(entry.build_geometry()).record()
    return res


def clear_capture_cache() -> None:
    """Drop cached captures (tests re-deriving traces from scratch)."""
    _RESULTS.clear()


def measured_compressibility_of(name: str) -> float:
    from repro.capture.compress import measured_compressibility

    return measured_compressibility(capture(name))


def capture_meta(name: str) -> Dict[str, object]:
    """Source-kernel metadata for one captured workload (``--list``)."""
    entry = CAPTURED[name]
    res = capture(name)
    return {
        "kernel": res.geom.kernel,
        "variant": res.geom.variant,
        "grid": res.geom.grid,
        "operands": tuple(op.name for op in res.geom.operands),
        "n_accesses": res.n_accesses,
        "footprint": res.footprint,
        "config": dict(entry.config),
        "compressibility": measured_compressibility_of(name),
    }


def save_kernel_trace(name: str, path: str) -> CaptureResult:
    """Persist one captured kernel trace through the standard
    ``save_trace`` path — the resulting ``.npz`` replays identically to the
    registered workload (tests/test_capture.py roundtrips it through
    ``register_trace_file``)."""
    from repro.core.sim.trace import save_trace

    res = capture(name)
    save_trace(path, res.trace,
               compressibility=measured_compressibility_of(name))
    return res


def register_captured_kernels(overwrite: bool = False) -> Tuple[str, ...]:
    """Register every catalog entry as a simulator workload.  Called from
    ``repro.core.sim.__init__`` so captured kernels are available out of
    the box; cheap because capture, measurement, and the kernel (jax)
    imports all happen lazily on first use."""
    from repro.core.sim.trace import WORKLOADS, WorkloadSpec, _register, replay_slice

    for name, entry in CAPTURED.items():
        if name in WORKLOADS and not overwrite:
            continue

        def generator(seed: int, footprint: int, n: int,
                      _name: str = name):
            return replay_slice(capture(_name).trace, seed, n)

        def compressibility(_name: str = name,
                            _cache: list = []) -> float:
            if not _cache:
                _cache.append(measured_compressibility_of(_name))
            return _cache[0]

        _register(WorkloadSpec(
            name=name, generator=generator, compressibility=compressibility,
            description=entry.description,
        ), overwrite=overwrite)
    return tuple(CAPTURED)
