"""Kernel-trace capture: drive the DS simulator with the Pallas kernels'
own block-level memory streams (DESIGN.md §2.8).

The subsystem derives deterministic ``(gaps, addrs, writes)`` traces from
the kernels' tiling geometry — no TPU, no jax at registration time — and
registers them as first-class simulator workloads (``fa_prefill``,
``fa_decode``, ``mamba_fwd``, ``bq_quant``), each with a
measured-from-data compressibility:

    from repro.core.sim import run_one
    run_one("fa_prefill", "daemon")          # works out of the box

    from repro.capture import save_kernel_trace
    save_kernel_trace("bq_quant", "bq.npz")  # standard .npz replay file

Layers: geometry (jax-free tiling model + disjoint operand regions) ->
recorder (grid walk, Pallas block-reuse semantics, roofline compute gaps)
-> compress (measured payload compressibility) -> workloads (catalog +
registry hook).  The per-kernel geometry shims live in each kernel's
``ops.py`` next to the jit wrapper they mirror.
"""
from repro.capture.compress import measure_ratio, measured_compressibility
from repro.capture.geometry import (
    KernelGeometry,
    Operand,
    assign_regions,
    block_line_addrs,
)
from repro.capture.recorder import CaptureResult, KernelTraceRecorder
from repro.capture.workloads import (
    CAPTURED,
    CapturedKernel,
    capture,
    capture_meta,
    clear_capture_cache,
    measured_compressibility_of,
    register_captured_kernels,
    save_kernel_trace,
)

__all__ = [
    "KernelGeometry", "Operand", "assign_regions", "block_line_addrs",
    "CaptureResult", "KernelTraceRecorder",
    "measure_ratio", "measured_compressibility",
    "CAPTURED", "CapturedKernel", "capture", "capture_meta",
    "clear_capture_cache", "measured_compressibility_of",
    "register_captured_kernels", "save_kernel_trace",
]
