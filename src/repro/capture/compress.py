"""Measured-from-data compressibility for captured kernel traces
(DESIGN.md §2.8).

The synthetic workloads carry hand-assigned compression ratios; captured
kernels get theirs **measured**: each operand region is filled with a
representative payload (what the kernel actually streams on realistic
inputs), zlib-compressed, and the per-operand ratios are combined weighted
by the bytes each operand moves over HBM in the captured launch.

Payload models (calibrated ratios in parentheses):

  f32_dense       dense gaussian f32 — attention Q/K/V/O tiles, SSM
                  B/C/state streams.  High-entropy mantissas: barely
                  compresses (~1.07) — "f32 attention states don't".
  f32_act_sparse  gate-sparsified heavy-tailed f32 activations (GLU-style
                  ~40% zeros, outlier channels) — block_quant's input
                  (~1.5).
  f32_pos         softplus-positive small values — discretization steps dt
                  (~1.13).
  f32_scales      per-block absmax scales (~1.12).
  int8_quant      per-block absmax int8 quantization of the sparse
                  heavy-tailed activations — block_quant's payload.
                  Outlier-driven scales concentrate the bulk of the
                  distribution near zero, so it compresses (~1.4):
                  "block_quant int8 payloads compress".

Everything is seeded and sample-capped, so measurement is deterministic
and cheap (a few MiB of zlib per captured kernel, once per process).
"""
from __future__ import annotations

import zlib
from typing import Dict

import numpy as np

from repro.capture.recorder import CaptureResult

SAMPLE_BYTES = 1 << 20  # per-operand measurement sample cap (1 MiB)
_QBLOCK = 128  # absmax quantization block (mirrors block_quant.BLOCK)


def _sparse_heavy(rng: np.random.Generator, n: int) -> np.ndarray:
    """Gate-sparsified heavy-tailed activations: student-t(3) channels with
    ~40% exact zeros (GLU gating / padding) — the documented structure of
    transformer MLP activations that makes their int8 form compressible."""
    x = rng.standard_t(3, n).astype(np.float32)
    x[rng.random(n) < 0.4] = 0.0
    return x


def payload_bytes(payload: str, n_bytes: int, seed: int = 0) -> bytes:
    """Representative region contents for one payload model."""
    rng = np.random.default_rng((seed, zlib.crc32(payload.encode())))
    if payload == "int8_quant":
        n = max(_QBLOCK, n_bytes // _QBLOCK * _QBLOCK)
        x = _sparse_heavy(rng, n).reshape(-1, _QBLOCK)
        s = np.abs(x).max(axis=1, keepdims=True) / 127.0
        s[s == 0] = 1.0
        return np.clip(np.round(x / s), -127, 127).astype(np.int8).tobytes()[:n_bytes]
    n = max(1, n_bytes // 4)
    if payload == "f32_act_sparse":
        x = _sparse_heavy(rng, n)
    elif payload == "f32_pos":
        x = np.log1p(np.exp(rng.standard_normal(n) * 0.5 - 2)).astype(np.float32)
    elif payload == "f32_scales":
        base = np.abs(rng.standard_t(3, (n // 8 + 1, 8))).max(axis=1) / 127.0
        x = np.repeat(base, 8)[:n].astype(np.float32)
    else:  # f32_dense
        x = rng.standard_normal(n).astype(np.float32)
    return x.tobytes()[:n_bytes]


def measure_ratio(payload: str, n_bytes: int = SAMPLE_BYTES,
                  seed: int = 0) -> float:
    raw = payload_bytes(payload, n_bytes, seed)
    return max(1.0, len(raw) / len(zlib.compress(raw, 6)))


def measured_compressibility(cap: CaptureResult, seed: int = 0) -> float:
    """Bytes-moved-weighted mean compression ratio over the capture's
    operand regions — the single per-workload ratio the link-compression
    model consumes (trace.py WorkloadSpec.compressibility)."""
    ops = {op.name: op for op in cap.geom.operands}
    ratios: Dict[str, float] = {}
    total = 0.0
    acc = 0.0
    for name, moved in cap.moved_bytes.items():
        if moved <= 0:
            continue
        op = ops[name]
        r = ratios.get(op.payload)
        if r is None:
            r = ratios[op.payload] = measure_ratio(
                op.payload, min(SAMPLE_BYTES, max(4096, op.nbytes)), seed)
        acc += moved * r
        total += moved
    return acc / total if total else 1.0
