"""Batch-engine parity tests (DESIGN.md §2.10): the lockstep batch core
must be cell-for-cell BIT-IDENTICAL to the Python oracle — same Metrics
dict, same derived seeds, same row order — on the full quick fig2 grid,
on fig5/fig6/jitter/nmcs subsets, and on randomized SimConfigs spanning
scheme x workload x jitter x n_ccs (hypothesis where installed, the
deterministic fallback sampler otherwise).  Also covers the dispatch
predicate (serving cells fall back to the oracle), batch serial == batch
parallel, the Sweep(engine=...) surface, and the non-gated wall_* ledger
keys."""
import json

import pytest

from repro.core.sim import (
    ENGINES,
    BatchCell,
    SimConfig,
    Sweep,
    covers,
    fig2_spec,
    fig5_scalability_spec,
    fig6_ablation_spec,
    run_batch,
    run_one,
    run_sweep,
    wall_stats,
    write_bench,
)

from conftest import given, settings, st  # hypothesis-or-fallback shim

N = 2_000  # the quick-CI fig2 cell size
FP = 2 << 20


def _dicts(res):
    return [r.metrics.as_dict() for r in res.rows]


def _assert_rows_identical(a, b):
    assert [r.axes for r in a.rows] == [r.axes for r in b.rows]
    assert [r.seed for r in a.rows] == [r.seed for r in b.rows]
    for ra, rb in zip(a.rows, b.rows):
        assert ra.metrics.as_dict() == rb.metrics.as_dict(), ra.axes


# --------------------------------------------------------------------------
# grid parity: batch == oracle, bit for bit
# --------------------------------------------------------------------------


def test_full_quick_fig2_grid_bit_identical():
    """The acceptance grid: all 48 quick fig2 cells (8 workloads x 6
    schemes), batch vs oracle, metrics dict equality — not almost-equal."""
    sw = fig2_spec(SimConfig(link_bw_frac=0.25), n_accesses=N)
    _assert_rows_identical(run_sweep(sw, engine="python"),
                           run_sweep(sw, engine="batch"))


def test_fig5_multicc_grid_bit_identical():
    """Multi-CC scalability cells (shared links, workload mixes)."""
    sw = fig5_scalability_spec(n_accesses=1_000)
    _assert_rows_identical(run_sweep(sw, engine="python"),
                           run_sweep(sw, engine="batch"))


def test_fig6_ablation_grid_bit_identical():
    """Ablation policies (adaptive granularity, no-compression, fixed-gran,
    dual-queue variants) — the widest policy-feature coverage."""
    sw = fig6_ablation_spec(n_accesses=1_000)
    _assert_rows_identical(run_sweep(sw, engine="python"),
                           run_sweep(sw, engine="batch"))


def test_jitter_and_nmcs_grid_bit_identical():
    """Bandwidth/latency jitter schedules and hashed multi-MC placement."""
    sw = Sweep(
        name="t_jitter",
        axes={"workload": ("dr", "st"),
              "bw_jitter": (0.0, 0.5),
              "lat_jitter": (0.0, 0.3),
              "n_mcs": (1, 2),
              "scheme": ("page", "daemon")},
        base=SimConfig(link_bw_frac=0.125, jitter_period=20_000,
                       mc_interleave="hash"),
        n_accesses=N, footprint=FP,
    )
    _assert_rows_identical(run_sweep(sw, engine="python"),
                           run_sweep(sw, engine="batch"))


def test_derive_seeds_parity():
    """Derived per-cell seeds (variance grids) resolve identically in both
    engines — the seed plumbing is shared, not duplicated."""
    sw = Sweep(
        name="t_seeds",
        axes={"workload": ("pr",), "seed": (0, 1, 2),
              "scheme": ("page", "daemon")},
        n_accesses=N, footprint=FP, derive_seeds=True,
    )
    _assert_rows_identical(run_sweep(sw, engine="python"),
                           run_sweep(sw, engine="batch"))


def test_batch_parallel_equals_batch_serial():
    """Worker fan-out only regroups cells; it never changes results."""
    sw = fig2_spec(SimConfig(link_bw_frac=0.25),
                   workloads=("pr", "dr", "st"), n_accesses=N)
    serial = run_sweep(sw, workers=1, engine="batch")
    par = run_sweep(sw, workers=3, engine="batch")
    _assert_rows_identical(serial, par)


# --------------------------------------------------------------------------
# randomized parity (the property test)
# --------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    workload=st.sampled_from(("pr", "bf", "dr", "st", "ml", "dr+st")),
    scheme=st.sampled_from(("local", "cacheline", "page", "both", "daemon",
                            "daemon_fifo", "both_dualq", "daemon_nocomp")),
    n_ccs=st.integers(1, 3),
    bw_jitter=st.floats(0.0, 0.5),
    lat_jitter=st.floats(0.0, 0.5),
    link_bw_frac=st.sampled_from((0.5, 0.25, 0.125)),
    seed=st.integers(0, 1 << 16),
)
def test_random_configs_bit_identical(workload, scheme, n_ccs, bw_jitter,
                                      lat_jitter, link_bw_frac, seed):
    """Randomized SimConfigs spanning scheme x workload x jitter x n_ccs:
    run_batch on one cell == run_one on the same cell, bit for bit."""
    cfg = SimConfig(n_ccs=n_ccs, bw_jitter=bw_jitter, lat_jitter=lat_jitter,
                    link_bw_frac=link_bw_frac, jitter_period=10_000,
                    jitter_seed=seed % 97)
    cell = BatchCell(workload, scheme, cfg, seed=seed, n_accesses=1_200,
                     footprint=FP)
    oracle = run_one(workload, scheme, cfg, seed=seed, n_accesses=1_200,
                     footprint=FP)
    got = run_batch([cell]).metrics[0]
    assert oracle.as_dict() == got.as_dict()


# --------------------------------------------------------------------------
# dispatch: coverage predicate + oracle fallback
# --------------------------------------------------------------------------


def test_covers_predicate():
    assert covers(SimConfig(), "daemon")
    assert not covers(SimConfig(serving_router="round_robin"), "daemon")
    assert not covers(SimConfig(), ("page", "daemon"))  # per-CC hetero list
    # routed fabric topologies (§2.11) are multi-hop: oracle only — even
    # 'direct', whose 1-hop metrics happen to match the legacy path
    assert not covers(SimConfig(topology="direct"), "daemon")
    assert not covers(SimConfig(topology="two_tier", oversub=2.0), "daemon")


def test_topology_cells_fall_back_to_oracle():
    """A sweep with a topology axis must produce oracle-identical rows
    under engine='batch': topology=None cells dispatch to the batch core,
    fabric cells fall back automatically."""
    sw = Sweep(
        name="t_topology",
        axes={"workload": ("pr",), "topology": (None, "single_switch"),
              "scheme": ("page", "daemon")},
        base=SimConfig(link_bw_frac=0.25),
        n_accesses=N, footprint=FP,
    )
    _assert_rows_identical(run_sweep(sw, workers=0, engine="batch"),
                           run_sweep(sw, workers=0, engine="python"))


def test_serving_cells_fall_back_to_oracle():
    """A sweep whose cells the batch core does not cover must still produce
    oracle-identical rows under engine='batch' (automatic fallback)."""
    sw = Sweep(
        name="t_serving",
        axes={"scheme": ("page", "daemon")},
        base=SimConfig(n_ccs=2, serving_router="round_robin", n_requests=4,
                       prefill_accesses=128, decode_steps=2,
                       decode_accesses=64, prefill_workload="st",
                       decode_workload="st"),
    )
    _assert_rows_identical(run_sweep(sw, engine="python"),
                           run_sweep(sw, engine="batch"))
    _assert_rows_identical(run_sweep(sw, engine="python"),
                           run_sweep(sw, workers=2, engine="batch"))


def test_run_batch_rejects_uncovered_cell():
    cell = BatchCell("pr", "daemon",
                     SimConfig(serving_router="round_robin"))
    with pytest.raises(ValueError, match="does not cover"):
        run_batch([cell])


# --------------------------------------------------------------------------
# Sweep/engine surface + ledger keys
# --------------------------------------------------------------------------


def test_engine_field_validated_and_recorded():
    assert ENGINES == ("python", "batch")
    with pytest.raises(ValueError, match="unknown engine"):
        Sweep(name="t", axes={}, engine="fortran")
    sw = Sweep(name="t", axes={"workload": ("pr",)},
               n_accesses=400, footprint=FP, engine="batch")
    res = run_sweep(sw)  # engine comes from the spec
    assert res.engine == "batch"
    assert run_sweep(sw, engine="python").engine == "python"
    with pytest.raises(ValueError, match="unknown engine"):
        run_sweep(sw, engine="fortran")
    # round-trips through the persistence schema
    assert type(res).from_dict(res.as_dict()).engine == "batch"


def test_wall_keys_in_ledger(tmp_path):
    """write_bench always attaches the non-gated wall_* throughput keys,
    and they carry through the ledger JSON."""
    sw = Sweep(name="t_wall", axes={"workload": ("pr",),
                                    "scheme": ("page", "daemon")},
               n_accesses=400, footprint=FP)
    res = run_sweep(sw, engine="batch")
    ws = wall_stats(res)
    assert set(ws) == {"wall_s", "wall_cells_per_s", "wall_cpu_s_per_cell"}
    assert ws["wall_s"] > 0 and ws["wall_cells_per_s"] > 0
    path = tmp_path / "BENCH_sim.json"
    write_bench(str(path), res, derived={"daemon_vs_page_geomean": 1.0})
    entry = json.loads(path.read_text())["sweeps"]["t_wall"]
    assert entry["engine"] == "batch"
    for k in ws:
        assert k in entry["derived"]
    assert entry["derived"]["daemon_vs_page_geomean"] == 1.0
