"""Fabric topology tests (DESIGN.md §2.11): ``topology=None`` legacy
parity with the committed goldens, ``direct`` == legacy bit-parity across
system shapes, byte conservation across multi-hop paths, per-port
arbitration class selection (daemon's dual queues ride every hop; the
``fabric`` policy component downgrades switch ports only), registry
fail-fast behavior, and the fig10 acceptance trend — tighter
oversubscription degrades page at least as much as daemon on
pointer-chase."""
import pytest

from repro.core.sim import (
    SimConfig,
    Simulator,
    available_topologies,
    build_topology,
    register_topology,
    run_one,
    unregister_topology,
)
from repro.core.sim.engine import DualQueueLink, FifoLink, SharedDualQueueLink
from repro.core.sim.fabric import PortSpec, TopologySpec
from repro.core.sim.trace import generate

from test_multicc import GOLD, N


def test_topology_none_bit_parity_with_goldens():
    """The legacy model (topology=None, the default) reproduces the
    committed goldens bit-for-bit for all six registered schemes — no
    fabric object is built and the flat per-MC links stay in place."""
    cfg = SimConfig(link_bw_frac=0.25)
    for key, exp in GOLD.items():
        w, s = key.split("/")
        m = run_one(w, s, cfg, seed=1, n_accesses=N)
        for name, v in exp.items():
            assert getattr(m, name) == v, (key, name)


@pytest.mark.parametrize("kw", [
    dict(),
    dict(n_ccs=2),
    dict(n_mcs=2),
    dict(uplink_bw=2.0),
    dict(n_ccs=2, n_mcs=2, uplink_bw=2.0),
    dict(bw_jitter=0.4, lat_jitter=0.3),
])
def test_direct_topology_is_bitwise_legacy(kw):
    """topology='direct' expresses the flat per-MC links as 1-hop fabric
    paths: the full Metrics dict is bit-identical to topology=None for
    every system shape — multi-CC, multi-MC, explicit uplink, weather."""
    base = SimConfig(link_bw_frac=0.25, **kw)
    w = "pr+st" if base.n_ccs > 1 else "pr"
    for s in ("page", "daemon", "both"):
        a = run_one(w, s, base, seed=3, n_accesses=3_000)
        b = run_one(w, s, base.with_(topology="direct"), seed=3,
                    n_accesses=3_000)
        assert a.as_dict() == b.as_dict(), (kw, s)


def _sim(workload, scheme, cfg, *, seed=0, n=4_000):
    """A Simulator instance (not just Metrics) so tests can inspect the
    fabric's per-port byte counters."""
    per = max(1, n // cfg.n_cores)
    parts = workload.split("+")  # '+'-mixes assign round-robin, as run_one
    if cfg.n_ccs == 1 and len(parts) == 1:
        traces = [generate(workload, seed=seed + j, footprint=16 << 20,
                           n=per) for j in range(cfg.n_cores)]
    else:
        traces = [
            [generate(parts[c % len(parts)],
                      seed=seed + c * cfg.n_cores + j,
                      footprint=16 << 20, n=per)
             for j in range(cfg.n_cores)]
            for c in range(cfg.n_ccs)
        ]
    sim = Simulator(cfg, scheme, traces, workload=workload, seed=seed)
    m = sim.run()
    return sim, m


def test_byte_conservation_single_switch():
    """Every byte sent into the fabric is delivered out of it, and the
    per-direction totals match the Metrics byte accounting; with a 2-hop
    path each tier's port-byte sum equals the direction total (no bytes
    appear or vanish at the switch)."""
    cfg = SimConfig(link_bw_frac=0.25, uplink_bw=4.0,
                    topology="single_switch")
    sim, m = _sim("wh", "page", cfg)
    fab = sim.fabric
    assert m.writebacks > 0  # the uplink direction actually carries bulk
    for d in ("down", "up"):
        assert fab.sent[d] > 0
        assert fab.sent[d] == pytest.approx(fab.delivered[d])
    assert m.net_bytes == pytest.approx(fab.sent["down"])
    assert m.uplink_bytes == pytest.approx(fab.sent["up"])
    down_nic = sum(ln.bytes for pn, ln in fab.ports.items()
                   if pn.startswith("d:mc"))
    down_sw = sum(ln.bytes for pn, ln in fab.ports.items()
                  if pn.startswith("d:sw>cc"))
    assert down_nic == pytest.approx(fab.sent["down"])
    assert down_sw == pytest.approx(fab.sent["down"])


def test_byte_conservation_two_tier_multi_cc():
    """On the 4-hop two_tier paths with multiple CCs and MCs, every tier —
    MC NICs, leaf->spine trunk, spine->leaf trunk, CC NICs — carries the
    same down-direction byte total."""
    cfg = SimConfig(link_bw_frac=0.25, n_ccs=2, n_mcs=2,
                    topology="two_tier", oversub=2.0)
    sim, m = _sim("pr+st", "daemon", cfg)
    fab = sim.fabric
    total = fab.sent["down"]
    assert total > 0 and total == pytest.approx(fab.delivered["down"])
    assert m.net_bytes == pytest.approx(total)
    tiers = (
        [pn for pn in fab.ports if pn.startswith("d:mc")],
        ["d:leafm>spine"],
        ["d:spine>leafc"],
        [pn for pn in fab.ports if pn.startswith("d:leafc>cc")],
    )
    for tier in tiers:
        assert sum(fab.ports[pn].bytes for pn in tier) == \
            pytest.approx(total), tier


def test_switch_ports_follow_the_fabric_policy_component():
    """Arbitration class per port: daemon (fabric=None) carries its
    dual-queue partitioning onto every hop; the page baseline gets FIFO
    ports throughout; daemon_fabfifo keeps dual queues at the endpoint
    NICs but downgrades switch-owned ports to FIFO — and is therefore
    strictly slower than daemon under switched pointer-chase contention
    while staying identical to daemon on topology=None."""
    cfg = SimConfig(link_bw_frac=0.25, topology="single_switch")
    by_scheme = {}
    for s in ("page", "daemon", "daemon_fabfifo"):
        sim, m = _sim("pr", s, cfg)
        by_scheme[s] = (sim, m)
    ports = {s: sim.fabric.ports for s, (sim, _) in by_scheme.items()}
    assert type(ports["page"]["d:mc0"]) is FifoLink
    assert type(ports["page"]["d:sw>cc0"]) is FifoLink
    assert type(ports["daemon"]["d:mc0"]) is DualQueueLink
    assert type(ports["daemon"]["d:sw>cc0"]) is DualQueueLink
    assert type(ports["daemon_fabfifo"]["d:mc0"]) is DualQueueLink
    assert type(ports["daemon_fabfifo"]["d:sw>cc0"]) is FifoLink
    assert by_scheme["daemon"][1].cycles < by_scheme["daemon_fabfifo"][1].cycles
    # the ablation is a no-op without a switched fabric (identical up to
    # the scheme label itself)
    flat = SimConfig(link_bw_frac=0.25)
    a = run_one("pr", "daemon", flat, seed=2, n_accesses=3_000).as_dict()
    b = run_one("pr", "daemon_fabfifo", flat, seed=2,
                n_accesses=3_000).as_dict()
    a.pop("scheme"), b.pop("scheme")
    assert a == b


def test_multi_cc_switch_ports_share_per_flow():
    """With several CCs behind one switch, daemon's switch ports arbitrate
    per (flow, class) lane — the shared dual-queue class — so one CC's
    page bulk cannot starve another CC's demand lines."""
    cfg = SimConfig(link_bw_frac=0.25, n_ccs=2, topology="single_switch")
    sim, _ = _sim("pr+st", "daemon", cfg)
    assert type(sim.fabric.ports["d:mc0"]) is SharedDualQueueLink
    assert type(sim.fabric.ports["d:sw>cc0"]) is SharedDualQueueLink


def test_switch_latency_is_charged_per_hop():
    """Raising switch_lat strictly slows a switched topology but leaves
    'direct' (no switch hops) untouched."""
    base = SimConfig(link_bw_frac=0.25, topology="single_switch")
    fast = run_one("pr", "daemon", base.with_(switch_lat=0),
                   seed=1, n_accesses=3_000)
    slow = run_one("pr", "daemon", base.with_(switch_lat=2_000),
                   seed=1, n_accesses=3_000)
    assert fast.cycles < slow.cycles
    d = SimConfig(link_bw_frac=0.25, topology="direct")
    a = run_one("pr", "daemon", d.with_(switch_lat=0), seed=1,
                n_accesses=3_000)
    b = run_one("pr", "daemon", d.with_(switch_lat=2_000), seed=1,
                n_accesses=3_000)
    assert a.as_dict() == b.as_dict()


def test_oversub_monotonicity_on_pointer_chase():
    """The fig10 acceptance trend at one representative cell: as the
    two_tier trunks tighten from non-blocking to 4:1, the page scheme
    degrades at least as much as daemon — the daemon-vs-page ratio never
    shrinks."""
    prev = 0.0
    for o in (1.0, 2.0, 4.0):
        cfg = SimConfig(link_bw_frac=0.25, topology="two_tier", oversub=o)
        p = run_one("pr", "page", cfg, n_accesses=4_000)
        d = run_one("pr", "daemon", cfg, n_accesses=4_000)
        ratio = p.cycles / d.cycles
        assert ratio >= prev, (o, ratio, prev)
        prev = ratio


def test_validation_fails_fast():
    with pytest.raises(ValueError, match="topology"):
        SimConfig(topology="clos")
    with pytest.raises(ValueError, match="oversub"):
        SimConfig(oversub=0.5)
    with pytest.raises(ValueError, match="switch_lat"):
        SimConfig(switch_lat=-1)
    with pytest.raises(KeyError, match="registered topologies"):
        build_topology("clos", n_ccs=1, n_mcs=1)
    with pytest.raises(ValueError, match="oversub"):
        build_topology("two_tier", n_ccs=1, n_mcs=1, oversub=0.25)
    with pytest.raises(ValueError, match="bad topology name"):
        register_topology("a/b")
    with pytest.raises(ValueError, match="already registered"):
        register_topology("direct")(lambda **kw: None)


def test_registry_contents_and_custom_topology():
    """The three built-ins are registered; a custom registered topology is
    immediately usable as SimConfig.topology and unregister removes it."""
    assert set(available_topologies()) >= {"direct", "single_switch",
                                           "two_tier"}

    @register_topology("t_hairpin", description="test-only single trunk")
    def _hairpin(*, n_ccs, n_mcs, oversub):
        ports = [PortSpec("d:trunk", down=True, switch=True),
                 PortSpec("u:trunk", down=False, switch=True)]
        down, up = {}, {}
        for j in range(n_mcs):
            ports.append(PortSpec(f"d:mc{j}", down=True, mc=j))
            ports.append(PortSpec(f"u:mc{j}", down=False, mc=j, switch=True))
            for i in range(n_ccs):
                down[(j, i)] = (f"d:mc{j}", "d:trunk")
                up[(i, j)] = ("u:trunk", f"u:mc{j}")
        return TopologySpec("t_hairpin", n_ccs, n_mcs, oversub,
                            tuple(ports), down, up)

    try:
        m = run_one("pr", "daemon", SimConfig(topology="t_hairpin"),
                    n_accesses=1_000)
        assert m.cycles > 0
    finally:
        unregister_topology("t_hairpin")
    assert "t_hairpin" not in available_topologies()
    with pytest.raises(ValueError, match="topology"):
        SimConfig(topology="t_hairpin")


def test_spec_validation_rejects_malformed_paths():
    """TopologySpec.validate fails fast on incomplete path tables, paths
    through undeclared ports, and direction mismatches."""
    p_down = PortSpec("d:x", down=True)
    p_up = PortSpec("u:x", down=False)
    with pytest.raises(ValueError, match="cover exactly"):
        TopologySpec("t", 1, 1, 1.0, (p_down, p_up), {},
                     {(0, 0): ("u:x",)}).validate()
    with pytest.raises(ValueError, match="undeclared port"):
        TopologySpec("t", 1, 1, 1.0, (p_down, p_up),
                     {(0, 0): ("d:ghost",)}, {(0, 0): ("u:x",)}).validate()
    with pytest.raises(ValueError, match="against its direction"):
        TopologySpec("t", 1, 1, 1.0, (p_down, p_up),
                     {(0, 0): ("u:x",)}, {(0, 0): ("u:x",)}).validate()
    with pytest.raises(ValueError, match="empty path"):
        TopologySpec("t", 1, 1, 1.0, (p_down, p_up),
                     {(0, 0): ()}, {(0, 0): ("u:x",)}).validate()
