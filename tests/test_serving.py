"""Serving-layer tests (DESIGN.md §2.9): determinism parity under the
process-pool sweep, request conservation and phase-ordering invariants
(hypothesis where installed, a deterministic fallback sampler otherwise),
replay-slice edge semantics, router/pool wiring, and the legacy-parity
lock — ``serving_router=None`` keeps all six schemes bit-identical to the
committed GOLD/GOLD_MCC goldens."""
import math

import numpy as np
import pytest

from repro.core.sim import (
    Metrics,
    SimConfig,
    Sweep,
    available_routers,
    build_requests,
    get_router,
    request_arrivals,
    run_one,
    run_sweep,
    serve_one,
)
from repro.core.sim.engine import Engine, SharedHeteroLink
from repro.core.sim.serving import ServingScheduler
from repro.core.sim.trace import generate, replay_slice

from conftest import given, settings, st  # hypothesis-or-fallback shim
from test_multicc import GOLD, GOLD_MCC, N


# small/fast serving cell: synthetic streaming phases, 2 CCs
def _cfg(**kw):
    base = dict(
        n_ccs=2, link_bw_frac=0.5, serving_router="round_robin",
        n_requests=6, offered_load=40.0,
        prefill_workload="st", decode_workload="st",
        prefill_accesses=128, decode_steps=2, decode_accesses=64,
    )
    base.update(kw)
    return SimConfig(**base)


# --------------------------------------------------------------------------
# determinism
# --------------------------------------------------------------------------


def test_cross_run_determinism_bit_identical():
    """Same (cfg, scheme, seed) -> bit-identical per-request records, run
    after run in one process (fresh Simulator + fresh RNG state each
    time)."""
    cfg = _cfg(serving_router="least_loaded")
    a = serve_one(cfg, "daemon", seed=7)
    b = serve_one(cfg, "daemon", seed=7)
    assert a.requests == b.requests
    assert (a.request_p50, a.request_p99, a.goodput) == \
           (b.request_p50, b.request_p99, b.goodput)
    c = serve_one(cfg, "daemon", seed=8)  # and the seed actually matters
    assert c.requests != a.requests


def test_sweep_serial_parallel_parity():
    """A serving sweep is cell-for-cell bit-identical between the serial
    runner and the process pool (the PR 1 parity lock, extended to the
    request layer: per-request completion cycles included)."""
    sw = Sweep(
        name="serving_parity",
        axes={
            "offered_load": (20.0, 60.0),
            "serving_router": ("round_robin", "disagg_prefill"),
            "scheme": ("cacheline", "daemon"),
        },
        base=_cfg(),
    )
    serial = run_sweep(sw, workers=1)
    pooled = run_sweep(sw, workers=4)
    assert len(serial) == len(pooled) == 8
    for rs, rp in zip(serial.rows, pooled.rows):
        assert rs.axes == rp.axes
        assert rs.metrics.requests == rp.metrics.requests
        assert rs.metrics.as_dict() == rp.metrics.as_dict()


def test_request_metrics_survive_ledger_round_trip():
    """Metrics.as_dict()/from_dict preserves the serving rollup (the
    BENCH_sim.json path for fig9 rows)."""
    m = serve_one(_cfg(), "daemon", seed=3)
    m2 = Metrics.from_dict(m.as_dict())
    assert m2.requests == m.requests
    assert m2.request_p99 == m.request_p99
    assert m2.requests_completed == m.requests_completed


# --------------------------------------------------------------------------
# property tests (hypothesis or the fallback sampler)
# --------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    load=st.floats(5.0, 150.0),
    router=st.sampled_from(("round_robin", "least_loaded", "disagg_prefill")),
    scheme=st.sampled_from(("cacheline", "daemon")),
    seed=st.integers(0, 50),
)
def test_request_conservation_at_drain(load, router, scheme, seed):
    """With no horizon the system drains: every offered request completes
    exactly once, with a monotone per-request lifecycle
    arrival <= t_start <= t_prefill_done <= t_done."""
    cfg = _cfg(offered_load=load, serving_router=router)
    m = serve_one(cfg, scheme, seed=seed)
    assert m.requests_completed == m.requests_offered == cfg.n_requests
    rids = [r["rid"] for r in m.requests]
    assert sorted(rids) == list(range(cfg.n_requests))  # none dup/dropped
    for r in m.requests:
        assert r["arrival"] <= r["t_start"] <= r["t_prefill_done"] <= r["t_done"]
        assert r["latency"] > 0


@settings(max_examples=6, deadline=None)
@given(
    load=st.floats(10.0, 100.0),
    horizon=st.floats(5e4, 4e5),
    seed=st.integers(0, 50),
)
def test_request_conservation_at_horizon(load, horizon, seed):
    """A horizon cut partitions the offered requests exactly into
    completed / in-flight / not-yet-arrived — none duplicated, none lost,
    and un-arrived records are exactly those whose arrival lies past the
    horizon."""
    cfg = _cfg(offered_load=load, serving_horizon=horizon)
    m = serve_one(cfg, "daemon", seed=seed)
    completed = [r for r in m.requests if not math.isnan(r["t_done"])]
    inflight = [r for r in m.requests
                if r["prefill_cc"] >= 0 and math.isnan(r["t_done"])]
    unarrived = [r for r in m.requests if r["prefill_cc"] < 0]
    assert len(completed) + len(inflight) + len(unarrived) == cfg.n_requests
    assert len(completed) == m.requests_completed
    assert sorted(r["rid"] for r in m.requests) == list(range(cfg.n_requests))
    for r in unarrived:
        assert r["arrival"] > horizon
    for r in completed + inflight:
        assert r["arrival"] <= horizon


@settings(max_examples=6, deadline=None)
@given(
    load=st.floats(5.0, 150.0),
    router=st.sampled_from(("round_robin", "least_loaded", "disagg_prefill")),
    scheme=st.sampled_from(("cacheline", "daemon")),
    seed=st.integers(0, 50),
)
def test_tail_ordering_p99_p50_min(load, router, scheme, seed):
    """p99 >= p50 >= the fastest request's latency, which itself can never
    beat an uncontended single-phase service time (> 0)."""
    cfg = _cfg(offered_load=load, serving_router=router)
    m = serve_one(cfg, scheme, seed=seed)
    lats = [r["latency"] for r in m.requests]
    assert m.request_p99 >= m.request_p50 >= min(lats) > 0
    assert max(lats) >= m.request_p99


# --------------------------------------------------------------------------
# routers, pools, heterogeneous policies
# --------------------------------------------------------------------------


def test_disagg_pools_and_phase_placement():
    """disagg_prefill splits the CCs into disjoint pools; every request
    prefills in the prefill pool and decodes in the decode pool."""
    cfg = _cfg(n_ccs=4, serving_router="disagg_prefill", n_requests=8)
    sched = ServingScheduler(cfg, "daemon", seed=2)
    assert set(sched.prefill_pool).isdisjoint(sched.decode_pool)
    assert set(sched.prefill_pool) | set(sched.decode_pool) == set(range(4))
    m = sched.run()
    assert m.requests_completed == 8
    for r in m.requests:
        assert r["prefill_cc"] in sched.prefill_pool
        assert r["decode_cc"] in sched.decode_pool


def test_router_registry_fails_fast():
    """Unknown routers fail fast at every entry point: get_router, the
    serving cell itself, and Sweep axis validation at declaration time."""
    assert set(available_routers()) >= {
        "round_robin", "least_loaded", "disagg_prefill"}
    with pytest.raises(KeyError, match="nonesuch"):
        get_router("nonesuch")
    with pytest.raises(KeyError, match="nonesuch"):
        run_one("st", "daemon", _cfg(serving_router="nonesuch"))
    with pytest.raises(KeyError, match="nonesuch"):
        Sweep(name="bad", axes={"serving_router": ("nonesuch",)}, base=_cfg())
    with pytest.raises(ValueError, match="n_ccs >= 2"):
        serve_one(_cfg(n_ccs=1, serving_router="disagg_prefill"), "daemon")


def test_heterogeneous_pool_policies():
    """Per-pool MovementPolicy overrides run (prefill pool on a bulk-share
    policy, decode pool on a line-protecting one) and are rejected for
    routers whose pools share CCs."""
    cfg = _cfg(n_ccs=4, serving_router="disagg_prefill",
               serving_prefill_policy="daemon_prefill",
               serving_decode_policy="daemon_decode")
    m = serve_one(cfg, "daemon", seed=1)
    assert m.requests_completed == cfg.n_requests
    assert m.scheme == "daemon_prefill|daemon_prefill|daemon_decode|daemon_decode"
    with pytest.raises(ValueError, match="disjoint pools"):
        serve_one(cfg.with_(serving_router="round_robin"), "daemon")


def test_serving_cell_routes_through_run_one():
    """run_one with serving_router set IS the serving cell (the sweep
    engine needs no special-casing beyond the config field)."""
    cfg = _cfg()
    a = run_one("ignored-label", "daemon", cfg, seed=5)
    b = serve_one(cfg, "daemon", seed=5)
    assert a.requests == b.requests


# --------------------------------------------------------------------------
# replay_slice edge semantics (decode stepping)
# --------------------------------------------------------------------------


def _toy_trace(n=10):
    gaps = np.arange(n, dtype=np.int64)
    addrs = (np.arange(n, dtype=np.int64) + 1) * 64
    writes = np.zeros(n, bool)
    return gaps, addrs, writes


def test_replay_slice_window_wraps_and_tiles():
    """A window spanning the trace end wraps to the start; n > len tiles
    the whole trace."""
    tr = _toy_trace(10)
    # seed=1 -> roll 9973 % 10 = 3: window [3..10) then wraps to [0..3)
    g, a, w = replay_slice(tr, seed=1, n=10)
    assert list(a // 64) == [4, 5, 6, 7, 8, 9, 10, 1, 2, 3]
    g, a, w = replay_slice(tr, seed=0, n=25)  # tiles 2.5x
    assert list(a[:10]) == list(a[10:20])
    assert len(a) == 25 and list(a[20:]) == list(a[:5])


def test_replay_slice_fails_fast_on_degenerate_windows():
    tr = _toy_trace(10)
    with pytest.raises(ValueError, match="n >= 1"):
        replay_slice(tr, seed=0, n=0)
    empty = (np.zeros(0, np.int64), np.zeros(0, np.int64), np.zeros(0, bool))
    with pytest.raises(ValueError, match="non-empty"):
        replay_slice(empty, seed=0, n=4)


def test_captured_slices_deterministic_per_workload():
    """Captured-kernel decode slices are a pure function of (workload,
    seed, n) — the per-request phase traces the serving layer schedules
    cannot silently shift replay phase between builds."""
    for wl in ("fa_prefill", "fa_decode"):
        a = generate(wl, seed=11, footprint=1 << 24, n=256)
        b = generate(wl, seed=11, footprint=1 << 24, n=256)
        c = generate(wl, seed=12, footprint=1 << 24, n=256)
        assert all(np.array_equal(x, y) for x, y in zip(a, b))
        assert not np.array_equal(a[1], c[1])  # seed rotates the window
    reqs = build_requests(_cfg(prefill_workload="fa_prefill",
                               decode_workload="fa_decode"), seed=4)
    reqs2 = build_requests(_cfg(prefill_workload="fa_prefill",
                                decode_workload="fa_decode"), seed=4)
    for r, r2 in zip(reqs, reqs2):
        assert r.arrival == r2.arrival
        for p, p2 in zip(r.phases, r2.phases):
            assert all(np.array_equal(x, y) for x, y in zip(p, p2))


def test_arrivals_are_open_loop_and_seeded():
    """The arrival process is strictly increasing, scheme-independent, and
    scales with offered load (a pure function of (cfg, seed))."""
    cfg = _cfg(n_requests=32)
    a = request_arrivals(cfg, seed=9)
    assert np.all(np.diff(a) > 0) and np.all(a > 0)
    assert np.array_equal(a, request_arrivals(cfg, seed=9))
    dense = request_arrivals(cfg.with_(offered_load=400.0), seed=9)
    assert dense[-1] < a[-1]  # higher load -> compressed arrivals


# --------------------------------------------------------------------------
# engine seam: the heterogeneous shared link
# --------------------------------------------------------------------------


def test_shared_hetero_link_conserves_transfers():
    """Every transfer on the mixed fifo/dual shared link completes exactly
    once, whatever the (flow, class) interleaving — the conservation
    invariant the per-CC-policy downlink construction relies on."""
    for flow_dual in ((True, False), (False, True, True), (True, True),
                      (False, False)):
        eng = Engine()
        link = SharedHeteroLink(eng, 4.0, 0.6, flow_dual)
        done = []
        k = 0
        for f in range(len(flow_dual)):
            for cls in ("line", "page"):
                for j in range(3):
                    eng.at(0.5 * k, lambda t, s=64 + 128 * j, ff=f, c=cls,
                           i=k: link.send(t, s, lambda a: done.append(i),
                                          c, ff))
                    k += 1
        eng.run()
        assert sorted(done) == list(range(k))


# --------------------------------------------------------------------------
# legacy parity: the request layer is pay-for-play
# --------------------------------------------------------------------------


def test_legacy_golden_parity_all_schemes():
    """serving_router=None (the default) keeps every committed golden
    bit-identical across all six schemes, single- and multi-CC — the
    request layer costs nothing unless a cell opts in."""
    assert SimConfig().serving_router is None
    for key, exp in GOLD.items():
        w, s = key.split("/")
        m = run_one(w, s, SimConfig(link_bw_frac=0.25), seed=1, n_accesses=N)
        for name, v in exp.items():
            assert getattr(m, name) == v, (key, name)
    cfg = SimConfig(link_bw_frac=0.25, n_ccs=2)
    for key, exp in GOLD_MCC.items():
        w, s = key.split("/")
        m = run_one(w, s, cfg, seed=1, n_accesses=N)
        for name, v in exp.items():
            assert getattr(m, name) == v, (key, name)
