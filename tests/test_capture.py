"""Kernel-trace capture tests (DESIGN.md §2.8): recorder determinism,
disjoint operand regions, Pallas block-reuse semantics in the emitted
stream, `.npz` roundtrip through the standard replay path, captured
workloads inside '+' mixes, measured compressibility ordering, the fig8
grid declaration, and drift locks between the ops.py geometry shims and
the kernels' own tiling constants."""
import numpy as np
import pytest

from repro.capture import (
    CAPTURED,
    assign_regions,
    capture,
    capture_meta,
    clear_capture_cache,
    measured_compressibility_of,
)
from repro.capture.workloads import CapturedKernel
from repro.core.sim import (
    SimConfig,
    available_workloads,
    compressibility_of,
    fig8_kernels_spec,
    generate,
    get_workload,
    register_trace_file,
    run_one,
)

KERNELS = ("fa_prefill", "fa_decode", "mamba_fwd", "bq_quant")


# ---------------- registration & out-of-the-box use ----------------


def test_captured_workloads_registered_at_import():
    assert set(KERNELS) <= set(available_workloads())
    for name in KERNELS:
        assert CAPTURED[name].description == get_workload(name).description


def test_run_one_works_out_of_the_box():
    m = run_one("fa_prefill", "daemon", n_accesses=2_000)
    assert m.accesses == 2_000 - 2_000 % 4  # n_cores=4 threads
    assert m.cycles > 0


def test_captured_workload_valid_in_mixes():
    cfg = SimConfig(n_ccs=2)
    m = run_one("fa_prefill+st", "daemon", cfg, n_accesses=2_000)
    assert len(m.per_cc) == 2
    assert {cc["workload"] for cc in m.per_cc} == {"fa_prefill", "st"}


def test_capture_meta_carries_source_kernel():
    meta = capture_meta("bq_quant")
    assert meta["kernel"] == "block_quant"
    assert meta["grid"] == (2, 4)
    assert meta["n_accesses"] > 0
    assert set(meta["operands"]) == {"x", "q", "scales"}


# ---------------- determinism ----------------


def test_recorder_determinism_bit_identical():
    a = capture("fa_prefill").trace
    clear_capture_cache()
    b = capture("fa_prefill").trace
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_generate_deterministic_and_seed_rotates_phase():
    g1, a1, w1 = generate("mamba_fwd", seed=3, n=5_000)
    g2, a2, w2 = generate("mamba_fwd", seed=3, n=5_000)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(g1, g2)
    np.testing.assert_array_equal(w1, w2)
    _, a3, _ = generate("mamba_fwd", seed=4, n=5_000)
    assert not np.array_equal(a1, a3)  # rotated replay phase


# ---------------- geometry / regions ----------------


def test_operand_regions_disjoint_and_page_aligned():
    for name in KERNELS:
        geom = CAPTURED[name].build_geometry()
        bases = assign_regions(geom)
        spans = sorted(
            (bases[op.name], bases[op.name] + op.nbytes, op.name)
            for op in geom.operands)
        for base, _, opname in spans:
            assert base % 4096 == 0, (name, opname)
        for (_, end_a, op_a), (start_b, _, op_b) in zip(spans, spans[1:]):
            assert end_a <= start_b, (name, op_a, op_b)


def test_block_runs_crossing_line_boundaries_keep_every_line():
    # a 40-byte run starting at byte 40 spans lines 0 AND 64; the line
    # emission must follow the run's actual byte span, not just its length
    from repro.capture.geometry import Operand, block_line_addrs

    op = Operand("z", shape=(4, 20), block=(1, 10), elem_bytes=4,
                 index_map=lambda i, j: (i, j))
    lines = block_line_addrs(op, base=0, block_idx=(0, 1))  # bytes 40..79
    np.testing.assert_array_equal(lines, [0, 64])


def test_trace_addresses_stay_inside_assigned_regions():
    for name in KERNELS:
        res = capture(name)
        geom = res.geom
        spans = {op.name: (res.regions[op.name],
                           res.regions[op.name] + op.nbytes)
                 for op in geom.operands}
        addrs = res.addrs
        covered = np.zeros(len(addrs), bool)
        for lo, hi in spans.values():
            covered |= (addrs >= (lo // 64) * 64) & (addrs < hi)
        assert covered.all(), name


def test_blocks_tile_arrays_exactly():
    # every operand's index map must stay within the block grid over the
    # whole launch grid (a drifted shim would walk out of bounds)
    for name in KERNELS:
        geom = CAPTURED[name].build_geometry()
        for op in geom.operands:
            n_blocks = tuple(s // b for s, b in zip(op.shape, op.block))
            for step in geom.steps():
                idx = op.index_map(*step)
                assert all(0 <= i < n for i, n in zip(idx, n_blocks)), (
                    name, op.name, step, idx)


# ---------------- stream structure (the captured signature) ----------------


def test_tile_bursts_are_line_dense():
    # inside a tile burst consecutive accesses step by exactly one line —
    # the high-spatial-reuse half of the captured signature
    _, addrs, _ = capture("fa_prefill").trace
    deltas = np.diff(addrs)
    assert (deltas == 64).mean() > 0.9


def test_inter_tile_jumps_present():
    # ... and the abrupt-jump half: region switches / tile jumps far apart
    _, addrs, _ = capture("fa_prefill").trace
    deltas = np.abs(np.diff(addrs))
    assert (deltas > 4096).sum() >= 100


def test_parked_q_tile_not_refetched():
    # flash q block is parked across the whole KV loop: q-region traffic
    # must be one fetch per (bh, qi), not per grid step
    res = capture("fa_prefill")
    geom = res.geom
    q = next(op for op in geom.operands if op.name == "q")
    n_q_fetches = geom.grid[0] * geom.grid[1]  # (bh, qi) combinations
    assert res.moved_bytes["q"] == n_q_fetches * q.block_nbytes


def test_output_writebacks_emitted_as_writes():
    _, addrs, writes = capture("bq_quant").trace
    assert writes.any()
    res = capture("bq_quant")
    lo = res.regions["q"]
    hi = lo + next(op for op in res.geom.operands if op.name == "q").nbytes
    in_q = (addrs >= lo) & (addrs < hi)
    assert writes[in_q].all()  # q region is write-only
    assert not writes[~in_q & (addrs < res.regions["q"])].any()  # x read-only


# ---------------- npz roundtrip ----------------


def test_npz_roundtrip_through_register_trace_file(tmp_path):
    from repro.capture import save_kernel_trace

    path = str(tmp_path / "fa_prefill_cap.npz")
    save_kernel_trace("fa_prefill", path)
    spec = register_trace_file(path)
    direct = generate("fa_prefill", seed=7, n=4_000)
    replay = spec(7, 0, 4_000)
    for a, b in zip(direct, replay):
        np.testing.assert_array_equal(a, b)
    assert spec.compressibility == pytest.approx(
        compressibility_of("fa_prefill"))


# ---------------- measured compressibility ----------------


def test_compressibility_measured_and_ordered():
    comps = {name: compressibility_of(name) for name in KERNELS}
    for name, c in comps.items():
        assert c >= 1.0, (name, c)
    # the headline distinction: block_quant's int8 payload compresses,
    # dense f32 attention states don't
    assert comps["bq_quant"] > comps["fa_prefill"] + 0.2
    assert comps["bq_quant"] > comps["fa_decode"] + 0.2
    # measurement is cached on the spec's lazy callable
    assert compressibility_of("bq_quant") == comps["bq_quant"]
    assert measured_compressibility_of("bq_quant") == pytest.approx(
        comps["bq_quant"])


# ---------------- shim drift locks ----------------


def test_shim_constants_match_kernels():
    import importlib

    bq = importlib.import_module("repro.kernels.block_quant.block_quant")
    fa = importlib.import_module(
        "repro.kernels.flash_attention.flash_attention")
    ms = importlib.import_module("repro.kernels.mamba_scan.mamba_scan")

    fa_geom = CAPTURED["fa_prefill"].build_geometry()
    q = next(op for op in fa_geom.operands if op.name == "q")
    assert q.block[1] == fa.DEFAULT_BQ or q.block[1] == q.shape[1]
    ms_geom = CAPTURED["mamba_fwd"].build_geometry()
    dt = next(op for op in ms_geom.operands if op.name == "dt")
    assert dt.block[1] == min(ms.CHUNK, dt.shape[1])
    assert dt.block[2] == min(ms.TILE_D, dt.shape[2])
    bq_geom = CAPTURED["bq_quant"].build_geometry()
    sc = next(op for op in bq_geom.operands if op.name == "scales")
    x = next(op for op in bq_geom.operands if op.name == "x")
    assert x.shape[1] // sc.shape[1] == bq.BLOCK


def test_fa_gqa_kv_sharing_matches_kernel_math():
    # the kv index map must reproduce flash_attention_pallas's GQA head
    # mapping: flat head j reads kv head j // g
    geom = CAPTURED["fa_prefill"].build_geometry()
    cfg = CAPTURED["fa_prefill"].config
    h, kvh = cfg["h"], cfg["kvh"]
    g = h // kvh
    k = next(op for op in geom.operands if op.name == "k")
    for bh in range(geom.grid[0]):
        idx = k.index_map(bh, 0, 0)
        assert idx[0] == (bh // h) * kvh + (bh % h) // g


# ---------------- fig8 grid ----------------


def test_fig8_spec_axes():
    sw = fig8_kernels_spec(n_accesses=2_000)
    assert sw.axes["workload"] == KERNELS
    assert "page" in sw.axes["scheme"] and "daemon" in sw.axes["scheme"]
    assert sw.axes["link_bw_frac"] == (0.125, 0.5, 1.0)


def test_unknown_captured_kernel_fails_fast():
    with pytest.raises(KeyError, match="catalog"):
        capture("not_a_kernel")


def test_catalog_entry_is_lazy():
    entry = CAPTURED["fa_prefill"]
    assert isinstance(entry, CapturedKernel)
    assert entry.module.startswith("repro.kernels.")
