"""Substrate tests: data pipeline determinism/sharding/resume, checkpoint
save/restore/corruption/async/gc, fault-tolerance state machines, elastic
mesh planning, schedules, and the end-to-end train driver (incl. crash +
resume and daemon movement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("zstandard", reason="zstandard not installed (see requirements.txt); repro.checkpoint needs it")
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, TokenPipeline
from repro.optim import adamw, schedule
from repro.runtime.elastic import plan_mesh, replan_after_failure
from repro.runtime.fault import (
    Action, HeartbeatMonitor, HostState, RunSupervisor, StragglerPolicy,
)


# ------------------------------- data -------------------------------------


def test_pipeline_deterministic_and_sharded():
    base = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=7)
    full = TokenPipeline(base)
    b_full = full.batch_at(5)
    full.close()
    # two DP shards reproduce exactly their halves of the global batch
    for rank in (0, 1):
        p = TokenPipeline(DataConfig(
            vocab_size=1000, seq_len=32, global_batch=8, seed=7,
            dp_rank=rank, dp_size=2,
        ))
        b = p.batch_at(5)
        np.testing.assert_array_equal(b["tokens"], b_full["tokens"][rank * 4:(rank + 1) * 4])
        p.close()


def test_pipeline_labels_shifted_and_resume():
    p = TokenPipeline(DataConfig(vocab_size=50, seq_len=16, global_batch=2))
    b = p.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # resume from a counter reproduces the same stream
    b3 = p.batch_at(3)
    p.close()
    p2 = TokenPipeline(DataConfig(vocab_size=50, seq_len=16, global_batch=2), start_step=3)
    first = next(p2)
    np.testing.assert_array_equal(first["tokens"], b3["tokens"])
    p2.close()


# ----------------------------- checkpoint ---------------------------------


def make_tree(key=0):
    k = jax.random.key(key)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = make_tree()
    mgr.save(10, tree, {"step": 10})
    out, extra = mgr.restore(None, tree)
    assert extra["step"] == 10
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["nested"]["b"]), np.asarray(tree["nested"]["b"]))


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = make_tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree, {"step": s})
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, make_tree())
    payload = tmp_path / "step_00000001" / "arrays" / "shard_0.npz.zst"
    data = bytearray(payload.read_bytes())
    data[10] ^= 0xFF
    payload.write_bytes(bytes(data))
    with pytest.raises(IOError):
        mgr.restore(1, make_tree())


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, make_tree())
    bad = {"a": jnp.zeros((4, 4)), "nested": {"b": jnp.zeros(10, jnp.int32)}}
    with pytest.raises(ValueError):
        mgr.restore(1, bad)


# ------------------------------- fault ------------------------------------


def test_heartbeat_states():
    m = HeartbeatMonitor(interval_s=1.0, suspect_after=2, dead_after=5)
    m.beat(0, now=100.0)
    assert m.state(0, now=100.5) == HostState.ALIVE
    assert m.state(0, now=103.0) == HostState.SUSPECT
    assert m.state(0, now=106.0) == HostState.DEAD
    assert m.state(99, now=0.0) == HostState.DEAD  # never beat


def test_straggler_escalation_ladder():
    p = StragglerPolicy(rebalance_after=2, exclude_after=4, evict_after=6)
    actions = {}
    for step in range(7):
        actions = p.observe_step({0: 1.0, 1: 1.0, 2: 2.0})  # host 2 is 2x median
    assert actions[0] == Action.NONE
    assert actions[2] == Action.EVICT
    # recovery resets the streak
    actions = p.observe_step({0: 1.0, 1: 1.0, 2: 1.0})
    assert actions[2] == Action.NONE


def test_supervisor_elastic_restart_on_death():
    sup = RunSupervisor(hosts=[0, 1, 2, 3], monitor=HeartbeatMonitor(interval_s=1.0))
    now = 1000.0
    sup.monitor.beat(3, now=now)  # host 3 goes silent afterwards
    for h in (0, 1, 2):
        sup.monitor.beat(h, now=now + 100)
    survivors = sup.tick({0: 1.0, 1: 1.0, 2: 1.0}, now=now + 100)
    assert survivors == [0, 1, 2]
    assert ("dead", 3) in sup.events


def test_elastic_mesh_planning():
    plan = plan_mesh(512, model_degree=16, global_batch=256, chips_per_pod=256)
    assert plan.shape == (2, 16, 16) and plan.spare_chips == 0
    # lose a host (8 chips): data degree shrinks, TP pinned
    smaller = replan_after_failure(plan, lost_chips=8, global_batch=256)
    assert smaller.model == 16
    assert smaller.used_chips <= 504
    assert smaller.data >= 1


# ------------------------------ schedules ---------------------------------


def test_wsd_schedule_shape():
    f = schedule.make("wsd", peak_lr=1.0, total_steps=1000, warmup_steps=100)
    assert float(f(0)) == 0.0
    assert abs(float(f(100)) - 1.0) < 1e-6
    assert abs(float(f(500)) - 1.0) < 1e-6  # stable phase
    assert float(f(999)) < 0.1  # decay tail
    c = schedule.make("cosine", peak_lr=1.0, total_steps=1000)
    assert float(c(1000)) <= 0.11


def test_adamw_reduces_loss_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params)
    for i in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(grads, state, params, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.2


# ----------------------------- train driver -------------------------------


def test_train_driver_with_checkpoint_resume(tmp_path):
    from repro.launch.train import train

    _, _, losses1 = train(
        "h2o-danube-1.8b", reduced=True, steps=8, global_batch=4, seq_len=32,
        ckpt_dir=str(tmp_path), ckpt_every=4, log_every=100,
    )
    assert losses1[-1] < losses1[0]
    # resume: continues from step 8's checkpoint without error
    _, _, losses2 = train(
        "h2o-danube-1.8b", reduced=True, steps=12, global_batch=4, seq_len=32,
        ckpt_dir=str(tmp_path), ckpt_every=4, resume=True, log_every=100,
    )
    assert len(losses2) == 4  # steps 8..12
    assert all(np.isfinite(losses2))


def test_train_driver_daemon_movement():
    from repro.launch.train import train

    _, _, losses = train(
        "minicpm-2b", reduced=True, steps=6, global_batch=4, seq_len=32,
        movement="daemon", num_microbatches=2, log_every=100,
    )
    assert losses[-1] < losses[0]


def test_serve_driver():
    from repro.launch.serve import serve

    r = serve("qwen3-14b", reduced=True, batch=2, prompt_len=32, gen_tokens=8)
    assert r["tokens"].shape == (2, 8)
    assert (r["tokens"] >= 0).all()
