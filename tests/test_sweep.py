"""Sweep-engine tests (DESIGN.md §6): process fan-out determinism, JSON
persistence, the BENCH_sim.json ledger, and the scenario axes (bandwidth
jitter, multi-MC interleaving) added for the paper's robustness grids."""
import json

import pytest

from repro.core.sim import (
    LinkSchedule,
    SimConfig,
    Sweep,
    SweepResult,
    cell_seed,
    run_one,
    run_sweep,
    scheme_geomean,
    write_bench,
)
from repro.core.sim.engine import Engine, FifoLink

N = 4_000  # accesses per cell: fast but dynamics-exercising


def small_sweep(**over):
    kw = dict(
        name="t",
        axes={"workload": ("pr", "st"), "scheme": ("page", "daemon")},
        n_accesses=N,
    )
    kw.update(over)
    return Sweep(**kw)


def test_parallel_equals_serial_cell_for_cell():
    """Determinism under process fan-out: same cells, same order, identical
    Metrics — the property that makes parallel figure runs trustworthy."""
    sw = small_sweep()
    serial = run_sweep(sw, workers=1)
    par = run_sweep(sw, workers=2)
    assert [r.axes for r in serial.rows] == [r.axes for r in par.rows]
    assert [r.metrics.as_dict() for r in serial.rows] == \
           [r.metrics.as_dict() for r in par.rows]
    assert par.workers == 2 and len(par) == len(sw) == 4


def test_json_roundtrip(tmp_path):
    res = run_sweep(small_sweep())
    p = str(tmp_path / "sweep.json")
    res.save_json(p)
    back = SweepResult.load_json(p)
    assert back.name == res.name and back.axes == res.axes
    assert [r.as_dict() for r in back.rows] == [r.as_dict() for r in res.rows]


def test_bench_ledger_merges_by_name(tmp_path):
    p = str(tmp_path / "BENCH_sim.json")
    a = run_sweep(small_sweep(name="a"))
    b = run_sweep(small_sweep(name="b", axes={"workload": ("pr",),
                                              "scheme": ("page", "daemon")}))
    write_bench(p, a, derived={"g": scheme_geomean(a.rows)})
    doc = write_bench(p, b)
    assert set(doc["sweeps"]) == {"a", "b"}
    with open(p) as f:
        on_disk = json.load(f)
    assert set(on_disk["sweeps"]) == {"a", "b"}
    assert on_disk["sweeps"]["a"]["derived"]["g"] > 1.0  # daemon beats page


def test_config_axes_and_derived_seeds():
    sw = Sweep(name="j", axes={"scheme": ("page", "daemon"), "workload": ("pr",),
                               "bw_jitter": (0.0, 0.5), "seed": (0, 1)},
               n_accesses=1_000, derive_seeds=True)
    res = run_sweep(sw)
    assert len(res) == 8
    # derived seeds are a pure function of the cell axes MINUS scheme, so
    # scheme-ratio comparisons stay trace-paired even under derive_seeds
    for r in res.rows:
        no_scheme = {k: v for k, v in r.axes.items() if k != "scheme"}
        assert r.seed == cell_seed(no_scheme, base_seed=r.axes["seed"])
    assert len({r.seed for r in res.rows}) == 4  # 2 jitter x 2 seed, shared
    by_pair = {}
    for r in res.rows:
        key = (r.axes["bw_jitter"], r.axes["seed"])
        by_pair.setdefault(key, set()).add(r.seed)
    assert all(len(s) == 1 for s in by_pair.values())  # page/daemon paired


def test_unknown_axis_rejected():
    with pytest.raises(ValueError, match="unknown sweep axis"):
        Sweep(name="x", axes={"not_a_field": (1,)})


def test_jitter_regression_daemon_degrades_less_than_page():
    """DESIGN.md §5: under bandwidth dips (fabric congestion) the page FIFO
    serializes critical lines behind delayed pages, while DaeMon's reserved
    line-queue share absorbs the dip — daemon must degrade less."""
    base = SimConfig(link_bw_frac=0.25, jitter_period=10_000)
    jit = base.with_(bw_jitter=0.5)
    degs = {}
    for s in ("page", "daemon"):
        c0 = run_one("pr", s, base, n_accesses=N).cycles
        cj = run_one("pr", s, jit, n_accesses=N).cycles
        degs[s] = cj / c0
    assert degs["page"] > 1.05, degs  # congestion actually hurts the baseline
    assert degs["daemon"] < degs["page"] * 0.9, degs


def test_jitter_deterministic_and_inert_at_zero():
    a = run_one("pr", "daemon", SimConfig(bw_jitter=0.4, lat_jitter=0.2),
                n_accesses=2_000)
    b = run_one("pr", "daemon", SimConfig(bw_jitter=0.4, lat_jitter=0.2),
                n_accesses=2_000)
    assert a.cycles == b.cycles and a.net_bytes == b.net_bytes
    plain = run_one("pr", "daemon", SimConfig(), n_accesses=2_000)
    zeroed = run_one("pr", "daemon", SimConfig(bw_jitter=0.0, lat_jitter=0.0),
                     n_accesses=2_000)
    assert plain.cycles == zeroed.cycles  # zero jitter == legacy model


def test_fifo_link_piecewise_schedule_integration():
    """FifoLink completion under a varying schedule matches brute-force
    numerical integration of bytes * dt across epochs."""
    sched = LinkSchedule(period=100, bw_jitter=0.8, lat_jitter=0.0, seed=7)
    link = FifoLink(Engine(), bw=4.0, sched=sched)
    start, size = 37.0, 1500.0
    done = link._finish(start, size)
    # numeric check: integrate capacity from start to done
    t, sent, dt = start, 0.0, 0.01
    while t < done - 1e-9:
        step = min(dt, done - t)
        sent += 4.0 * sched.bw_mult(t) * step
        t += step
    assert sent == pytest.approx(size, rel=1e-3)


def test_mc_interleave_modes():
    cfgs = {m: SimConfig(n_mcs=4, mc_interleave=m)
            for m in ("page", "hash", "single")}
    cycles = {m: run_one("pr", "daemon", c, n_accesses=N).cycles
              for m, c in cfgs.items()}
    # all modes run and are deterministic; 'single' (one shared link) can
    # never beat hashed spreading across 4 independent links
    assert cycles["hash"] <= cycles["single"] * 1.01, cycles
    with pytest.raises(ValueError, match="mc_interleave"):
        run_one("pr", "daemon", SimConfig(mc_interleave="bogus"), n_accesses=100)


def test_nmcs_sweep_runs_and_helps_page_scheme():
    """More MCs = more aggregate links: the page scheme's congestion eases,
    so daemon's advantage shrinks but must not invert (robustness)."""
    sw = Sweep(
        name="nmcs",
        axes={"workload": ("pr",), "n_mcs": (1, 4), "scheme": ("page", "daemon")},
        base=SimConfig(link_bw_frac=0.125, mc_interleave="hash"),
        n_accesses=N,
    )
    res = run_sweep(sw)
    g = res.grid("n_mcs", "scheme")
    adv = {n: g[(n, "page")].metrics.cycles / g[(n, "daemon")].metrics.cycles
           for n in (1, 4)}
    assert adv[4] <= adv[1] * 1.1, adv
    assert adv[4] >= 0.95, adv
