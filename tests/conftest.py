"""Shared test fixtures: the hypothesis-or-fallback shim.

Property tests import ``given``/``settings``/``st`` from here and PASS
either way.  With hypothesis installed they get real shrinking/coverage;
without it (the pinned CI image has no pip) a deterministic fallback
sampler — seeded per test name — drives the same strategies through a
fixed number of examples.  Set ``REPRO_FORCE_HYPOTHESIS_FALLBACK=1`` to
exercise the fallback path even where hypothesis is available (CI runs
the property files both ways).

The fallback supports exactly the strategy surface the suite uses:
``integers``, ``floats``, ``sampled_from``, ``lists``, ``tuples`` — and
only keyword-style ``@given(name=strategy, ...)``.  Extend it here when a
test needs more; never re-inline the shim in a test file.
"""
import os
import zlib

import numpy as np

_FORCE_FALLBACK = bool(os.environ.get("REPRO_FORCE_HYPOTHESIS_FALLBACK"))

try:
    if _FORCE_FALLBACK:
        raise ImportError("REPRO_FORCE_HYPOTHESIS_FALLBACK set")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # no pip install available: run the fallback sampler
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _St:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [elem.draw(rng) for _ in
                             range(int(rng.integers(min_size, max_size + 1)))])

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    st = _St()

    def settings(max_examples=6, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            n_ex = getattr(fn, "_max_examples", 6)

            def wrapper():
                rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
                for _ in range(n_ex):
                    fn(**{k: s.draw(rng) for k, s in strategies.items()})

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
