"""Policy & workload registry tests (DESIGN.md §2.6): registration
semantics, fail-fast name validation, ablation compositions landing
strictly between 'page' and 'daemon' (the paper's synergy claim), the new
trace sources (phase-changing, .npz replay), and Metrics round-trips —
all without touching `Simulator.miss()` dispatch internals."""
import numpy as np
import pytest

from repro.core.sim import (
    ABLATION_POLICIES,
    Metrics,
    MovementPolicy,
    SimConfig,
    Sweep,
    available_policies,
    available_workloads,
    generate,
    geomean,
    get_policy,
    get_workload,
    register_policy,
    register_trace_file,
    register_workload,
    run_one,
    run_sweep,
    save_trace,
    unregister_policy,
    unregister_workload,
)

N = 3_000


# ---------------- registry behavior ----------------


def test_legacy_schemes_are_registered_compositions():
    assert set(available_policies()) >= {
        "local", "page", "page_free", "cacheline", "both", "daemon"}
    d = get_policy("daemon")
    assert (d.granularity, d.partitioning, d.compression, d.throttle) == \
        ("adaptive", "dual", "link", True)
    p = get_policy("page")
    assert (p.granularity, p.partitioning, p.compression, p.throttle) == \
        ("page", "fifo", "off", False)
    assert get_policy("both").page_carries_requests is False
    assert get_policy("page_free").free_transfers is True


def test_duplicate_policy_registration_raises():
    pol = MovementPolicy(name="dup_test_pol")
    register_policy(pol)
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_policy(pol)
        register_policy(pol, overwrite=True)  # explicit overwrite is allowed
    finally:
        unregister_policy("dup_test_pol")


def test_unknown_policy_lists_choices():
    with pytest.raises(KeyError, match=r"registered policies: .*daemon"):
        run_one("pr", "no_such_policy", n_accesses=100)


def test_unknown_workload_lists_choices():
    with pytest.raises(KeyError, match=r"registered workloads: .*pr"):
        run_one("no_such_workload", "daemon", n_accesses=100)
    # '+' mixes validate every part
    with pytest.raises(KeyError, match="no_such_workload"):
        run_one("pr+no_such_workload", "daemon",
                SimConfig(n_ccs=2), n_accesses=100)


def test_sweep_validates_names_at_declaration():
    with pytest.raises(KeyError, match="registered policies"):
        Sweep(name="x", axes={"scheme": ("page", "bogus")})
    with pytest.raises(KeyError, match="registered workloads"):
        Sweep(name="x", axes={"workload": ("pr+bogus",)})


def test_policy_component_validation():
    with pytest.raises(ValueError, match="granularity"):
        MovementPolicy(name="bad", granularity="huge")
    with pytest.raises(ValueError, match="partitioning"):
        MovementPolicy(name="bad", partitioning="triple")
    with pytest.raises(ValueError, match="line_share"):
        MovementPolicy(name="bad", line_share=1.5)


def test_duplicate_workload_registration_raises():
    @register_workload("dup_test_wl")
    def gen(seed, footprint, n):  # pragma: no cover - never generated
        raise AssertionError
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_workload("dup_test_wl")(gen)
    finally:
        unregister_workload("dup_test_wl")


def test_simconfig_fails_fast():
    with pytest.raises(ValueError, match="mc_interleave"):
        SimConfig(mc_interleave="bogus")
    with pytest.raises(ValueError, match="n_ccs"):
        SimConfig(n_ccs=0)
    with pytest.raises(ValueError, match="line_share"):
        SimConfig(line_share=0.0)
    with pytest.raises(ValueError, match="page_bytes"):
        SimConfig(line_bytes=64, page_bytes=100)
    with pytest.raises(ValueError, match="bw_jitter"):
        SimConfig(bw_jitter=1.5)


# ---------------- custom registrations, no engine edits ----------------


def test_custom_policy_runs_by_name():
    """A fresh composition registered at runtime is immediately usable by
    its string name everywhere — the registry IS the dispatch."""
    register_policy(MovementPolicy(
        name="tpol_lowshare", granularity="adaptive", partitioning="dual",
        compression="link", throttle=True, line_share=0.2))
    try:
        m = run_one("pr", "tpol_lowshare", SimConfig(link_bw_frac=0.25),
                    n_accesses=N)
        assert m.scheme == "tpol_lowshare" and m.cycles > 0
        # the per-policy line_share override takes effect: a different
        # bandwidth split is a different simulation
        d = run_one("pr", "daemon", SimConfig(link_bw_frac=0.25), n_accesses=N)
        assert m.cycles != d.cycles
    finally:
        unregister_policy("tpol_lowshare")


def test_custom_workload_runs_by_name_and_in_mixes():
    @register_workload("twl_stride", compressibility=2.5)
    def stride(seed, footprint, n):
        addrs = (np.arange(n, dtype=np.int64) * 192) % footprint
        return (np.full(n, 20, np.int64), addrs, np.zeros(n, bool))
    try:
        m = run_one("twl_stride", "daemon", n_accesses=N)
        assert m.accesses > 0
        mix = run_one("twl_stride+pr", "daemon", SimConfig(n_ccs=2),
                      n_accesses=N)
        assert [d["workload"] for d in mix.per_cc] == ["twl_stride", "pr"]
    finally:
        unregister_workload("twl_stride")


# ---------------- ablation compositions (paper synergy) ----------------


def test_ablations_land_strictly_between_page_and_daemon():
    """Each ablated policy removes one technique: every one must beat the
    page baseline on the geomean yet lose to the full daemon synergy."""
    cfg = SimConfig(link_bw_frac=0.125)
    wls = ("pr", "nw", "dr", "ml", "ph")
    n = 4_000  # >= 1000 accesses/thread so 'ph' actually alternates phases
    base = {w: run_one(w, "page", cfg, n_accesses=n).cycles for w in wls}
    gm = {}
    for p in ABLATION_POLICIES + ("daemon",):
        gm[p] = geomean(
            base[w] / run_one(w, p, cfg, n_accesses=n).cycles for w in wls)
    for p in ABLATION_POLICIES:
        assert 1.0 < gm[p] < gm["daemon"], (p, gm)


def test_nocomp_ablation_disables_compression_only():
    cfg = SimConfig(link_bw_frac=0.125)
    full = run_one("pr", "daemon", cfg, n_accesses=N)
    nocomp = run_one("pr", "daemon_nocomp", cfg, n_accesses=N)
    assert full.bytes_saved_compression > 0
    assert nocomp.bytes_saved_compression == 0
    assert nocomp.net_bytes > full.net_bytes


def test_page_dualq_is_a_null_ablation():
    """Page-granularity traffic on the dual-queue link has no line class to
    protect — it must match the FIFO page scheme's cycle count closely."""
    cfg = SimConfig(link_bw_frac=0.25)
    a = run_one("pr", "page", cfg, n_accesses=N)
    b = run_one("pr", "page_dualq", cfg, n_accesses=N)
    assert b.cycles == pytest.approx(a.cycles, rel=1e-6)


def test_ablation_policies_in_sweep_axes():
    sw = Sweep(
        name="abl",
        axes={"workload": ("pr",),
              "scheme": ("page", "daemon_fifo", "daemon")},
        base=SimConfig(link_bw_frac=0.25),
        n_accesses=2_000,
    )
    res = run_sweep(sw, workers=2)  # registry survives process fan-out
    g = res.grid("scheme")
    assert g[("page",)].metrics.cycles > g[("daemon_fifo",)].metrics.cycles \
        > g[("daemon",)].metrics.cycles * 0.99


# ---------------- new trace sources ----------------


def test_phase_workload_registered_with_metadata():
    spec = get_workload("ph")
    assert spec.compressibility == pytest.approx(2.8)
    gaps, addrs, writes = generate("ph", n=4_000)
    assert len(gaps) == len(addrs) == len(writes) == 4_000
    # both phases present: a sequential lower-half scan and upper-half hops
    assert addrs.min() < 16 << 19 and addrs.max() > 16 << 19


def test_phase_workload_rewards_adaptivity():
    """On the phase-changing source the fixed-granularity ablation must not
    beat the adaptive daemon (the phase is what adaptivity tracks)."""
    cfg = SimConfig(link_bw_frac=0.125)
    d = run_one("ph", "daemon", cfg, n_accesses=4_000)
    f = run_one("ph", "daemon_fixed_gran", cfg, n_accesses=4_000)
    p = run_one("ph", "page", cfg, n_accesses=4_000)
    assert d.cycles <= f.cycles * 1.01
    assert d.cycles < p.cycles  # and the phase mix still favors daemon


def test_trace_replay_roundtrip(tmp_path):
    path = str(tmp_path / "cap.npz")
    save_trace(path, generate("pr", seed=3, n=2_000), compressibility=3.3)
    spec = register_trace_file(path)
    assert spec.compressibility == pytest.approx(3.3)
    assert path in available_workloads()
    # replay is deterministic and seed-rotated (threads out of phase)
    g0, a0, w0 = spec.trace(seed=0, n=500)
    g1, a1, w1 = spec.trace(seed=1, n=500)
    assert len(a0) == 500 and not np.array_equal(a0, a1)
    ref = generate("pr", seed=3, n=2_000)
    assert np.array_equal(a0, ref[1][:500])
    m = run_one(path, "daemon", n_accesses=N)
    assert m.remote_misses > 0


def test_trace_replay_auto_registers_by_path_and_in_mixes(tmp_path):
    path = str(tmp_path / "auto.npz")
    save_trace(path, generate("st", seed=0, n=2_000))
    # never explicitly registered: the .npz suffix auto-registers on lookup
    m = run_one("pr+" + path, "daemon", SimConfig(n_ccs=2), n_accesses=N)
    assert [d["workload"] for d in m.per_cc] == ["pr", path]
    with pytest.raises(FileNotFoundError):
        get_workload(str(tmp_path / "missing.npz"))


# ---------------- Metrics round-trip ----------------


def test_metrics_roundtrip_with_per_cc():
    m = run_one("pr+st", "daemon", SimConfig(n_ccs=2, link_bw_frac=0.25),
                n_accesses=2_000)
    assert len(m.per_cc) == 2  # non-empty rollup
    d = m.as_dict()
    back = Metrics.from_dict(d)
    assert back.as_dict() == d
    assert back.per_cc == m.per_cc
    assert back.avg_access_cost == pytest.approx(m.avg_access_cost)


def test_metrics_roundtrip_ignores_derived_keys():
    m = run_one("st", "page", n_accesses=1_000)
    d = m.as_dict()
    d["avg_access_cost"] = -123.0  # derived: must be ignored on the way in
    back = Metrics.from_dict(d)
    assert back.avg_access_cost == m.avg_access_cost
    assert back.per_cc == []
