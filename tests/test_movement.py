"""Movement-engine tests: compressed/chunked collectives under shard_map on
8 fake CPU devices (subprocess — device count locks at first jax init), the
selection unit's hysteresis, and the daemon train step's numerics."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str) -> dict:
    """Run `body` with 8 fake devices; it must print a final json line."""
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from functools import partial
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core import movement as mv
        mesh = jax.make_mesh((8,), ("data",))
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True, env=env,
        timeout=420,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_compressed_all_gather_roundtrip():
    out = run_in_subprocess(
        """
        x = jax.random.normal(jax.random.key(0), (16, 256), jnp.float32)
        xs = jax.device_put(x, NamedSharding(mesh, P("data")))
        def f(xl):
            return mv.compressed_all_gather(xl, "data", compress="int8")
        g = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                              out_specs=P("data")))(xs)
        # every shard gathered the same full tensor; check against x
        full = np.asarray(g).reshape(8, 16, 256)[0]
        err = np.abs(full - np.asarray(x)).max()
        bound = np.abs(np.asarray(x)).reshape(16, 2, 128).max(-1).max() / 127
        print(json.dumps({"err": float(err), "bound": float(bound)}))
        """
    )
    assert out["err"] <= out["bound"] * 1.01


@pytest.mark.slow
def test_chunked_all_gather_matches_plain():
    out = run_in_subprocess(
        """
        x = jax.random.normal(jax.random.key(1), (24, 128), jnp.float32)
        xs = jax.device_put(x, NamedSharding(mesh, P("data")))
        def f(xl):
            plain = jax.lax.all_gather(xl, "data", tiled=True)
            dual = mv.chunked_all_gather(xl, "data", page_chunks=3,
                                         critical_rows=1, compress_pages="bf16")
            return plain, dual
        p, d = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                                 out_specs=(P("data"), P("data"))))(xs)
        err = float(np.abs(np.asarray(p, np.float32) - np.asarray(d, np.float32)).max())
        print(json.dumps({"err": err}))
        """
    )
    assert out["err"] < 0.02  # bf16 pages round at ~1e-2 relative


@pytest.mark.slow
def test_compressed_grad_sync_error_feedback_converges():
    """Error feedback: mean of int8-synced grads over steps tracks the true
    mean (residual prevents bias accumulation)."""
    out = run_in_subprocess(
        """
        key = jax.random.key(2)
        g_true = jax.random.normal(key, (8, 8, 128), jnp.float32)  # per-device grads
        gs = jax.device_put(g_true.reshape(64, 128),
                            NamedSharding(mesh, P("data")))
        def f(gl, res):
            gm, new_res = mv.compressed_grad_sync(gl, "data", res, compress="int8")
            return gm, new_res
        fm = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                               out_specs=(P("data"), P("data"))))
        res = jnp.zeros((64, 128), jnp.float32)
        acc = np.zeros((8, 128), np.float32)
        steps = 6
        for _ in range(steps):
            gm, res = fm(gs, res)
            acc += np.asarray(gm).reshape(8, 8, 128)[0]
        true_mean = np.asarray(g_true).mean(0)
        err = np.abs(acc / steps - true_mean).max()
        scale = np.abs(np.asarray(g_true)).max() / 127
        print(json.dumps({"err": float(err), "scale": float(scale)}))
        """
    )
    # with error feedback the time-averaged estimate is much tighter than one
    # quantization step
    assert out["err"] <= out["scale"] * 3


def test_selection_unit_hysteresis():
    from repro.core.movement import SelectionUnit

    su = SelectionUnit(hold_steps=5)
    assert su.config().param_gather == "bf16"
    # sustained collective pressure escalates once per hold window
    su.observe(0, collective_s=10.0, compute_s=1.0)
    assert su._level == 2  # noqa: SLF001 — starts at 1, escalates
    for s in range(1, 4):
        su.observe(s, 10.0, 1.0)
    assert su._level == 2  # capped
    # relaxation requires the hold window to elapse
    su.observe(5, 0.01, 1.0)
    assert su._level == 1
    su.observe(6, 0.01, 1.0)
    assert su._level == 1  # hysteresis holds
    su.observe(11, 0.01, 1.0)
    assert su._level == 0


def test_daemon_train_step_numerics():
    """The daemon step trains: loss decreases on a tiny model, and the bf16
    working copy equals master.astype(bf16)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import movement as mv
    from repro.launch import steps
    from repro.models import model as M
    from repro.models import nn

    cfg = get_config("minicpm-2b").reduced()
    specs = M.model_specs(cfg)
    master = nn.init_params(specs, jax.random.key(0))
    state = mv.init_state(master)
    params = mv.working_copy(master, mv.DAEMON_DEFAULT)
    step = steps.make_train_step(cfg, movement="daemon", num_microbatches=2)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)), jnp.int32),
    }
    jstep = jax.jit(step)
    losses = []
    for _ in range(5):
        params, state, metrics = jstep(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses
    lw = jax.tree.leaves(params)[0]
    mw = jax.tree.leaves(state.master)[0]
    np.testing.assert_array_equal(
        np.asarray(lw), np.asarray(mw.astype(jnp.bfloat16))
    )


def test_daemon_int8_grad_sync_step():
    """grad_sync='int8' carries a residual and still trains."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import movement as mv
    from repro.launch import steps
    from repro.models import model as M
    from repro.models import nn
    from repro.optim import schedule

    cfg = get_config("h2o-danube-1.8b").reduced()
    specs = M.model_specs(cfg)
    master = nn.init_params(specs, jax.random.key(1))
    state = mv.init_state(master)
    params = mv.working_copy(master, mv.DAEMON_AGGRESSIVE)
    step = mv.make_daemon_train_step(
        cfg, sched=schedule.make("cosine", peak_lr=1e-3, total_steps=100),
        engine_cfg=mv.DAEMON_AGGRESSIVE, num_microbatches=1,
    )
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
    }
    jstep = jax.jit(step)
    l0 = None
    for i in range(4):
        params, state, metrics = jstep(params, state, batch)
        if i == 0:
            l0 = float(metrics["loss"])
    assert float(metrics["loss"]) < l0
    res_norm = sum(float(jnp.sum(jnp.abs(r))) for r in jax.tree.leaves(state.residual))
    assert res_norm > 0  # error feedback is live
