"""End-to-end behaviour tests for the system as a whole: the paper's claims
hold on the faithful layer, the deliverable artifacts exist and are
coherent, and the framework layers compose (model zoo x movement engine x
substrates)."""
import glob
import json
import os

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_paper_headline_claims_fast():
    """Reduced-size version of the 2.39x/3.06x geomean validation (the full
    version runs in tests/test_sim.py::test_paper_claims)."""
    from repro.core.sim import paper_claims

    r = paper_claims(bw_fracs=(0.125,), n_accesses=8_000)
    assert r["perf_speedup_geomean"] >= 1.7
    assert r["access_cost_reduction_geomean"] >= 1.7


def test_all_archs_have_live_cells_and_specs():
    from repro.configs import ARCHS, get_config
    from repro.models import model as M

    assert len(ARCHS) == 10
    total_cells = 0
    for a in ARCHS:
        cfg = get_config(a)
        cells = cfg.live_cells()
        total_cells += len(cells)
        M.model_specs(cfg)
        assert M.param_count(cfg) > 5e7  # full configs are full-size (whisper-base = 80M)
    assert total_cells == 33  # 40 nominal - 7 documented long_500k skips


@pytest.mark.skipif(
    not glob.glob(os.path.join(REPO, "artifacts", "dryrun", "*.json")),
    reason="dry-run artifacts not generated (run python -m repro.launch.dryrun --all)",
)
def test_dryrun_artifacts_complete_and_ok():
    """Deliverable (e): every live cell compiled on BOTH production meshes."""
    recs = [
        json.load(open(f))
        for f in glob.glob(os.path.join(REPO, "artifacts", "dryrun", "*.json"))
    ]
    ok = [r for r in recs if r.get("ok")]
    cells = {(r["arch"], r["cell"], r["mesh"]) for r in ok}
    meshes = {m for _, _, m in cells}
    assert {"16x16", "2x16x16"} <= meshes
    per_mesh = {m: len([c for c in cells if c[2] == m]) for m in ("16x16", "2x16x16")}
    assert per_mesh["16x16"] >= 33 and per_mesh["2x16x16"] >= 33, per_mesh
    for r in ok:
        assert r["flops"] > 0 and r["hbm_bytes"] > 0
        assert r["bottleneck"] in ("compute", "memory", "collective")


def test_movement_engine_composes_with_every_family():
    """working_copy + daemon state machinery handles every arch's pytree."""
    import jax

    from repro.configs import ARCHS, get_config
    from repro.core import movement as mv
    from repro.models import model as M
    from repro.models import nn

    for a in ARCHS[:4]:  # one per family class is enough for composition
        cfg = get_config(a).reduced()
        master = nn.init_params(M.model_specs(cfg), jax.random.key(0))
        state = mv.init_state(master)
        params = mv.working_copy(master, mv.DAEMON_DEFAULT)
        assert jax.tree.structure(params) == jax.tree.structure(master)
        assert all(p.dtype == "bfloat16" for p in jax.tree.leaves(params))
        assert jax.tree.structure(state.residual) == jax.tree.structure(master)


def test_selection_unit_drives_movement_levels_from_roofline_terms():
    """The controller consumes exactly what the dry-run produces."""
    from repro.core.movement import SelectionUnit

    su = SelectionUnit(hold_steps=1)
    # feed it a collective-bound cell (qwen3 decode A0): escalates
    cfg = su.observe(0, collective_s=2.04, compute_s=0.0031)
    assert cfg.grad_sync == "int8" or cfg.expert_weights == "int8"
    # and a compute-bound profile: relaxes over time
    for s in range(1, 6):
        cfg = su.observe(s, collective_s=0.01, compute_s=2.0)
    assert cfg.page_chunks == 1
