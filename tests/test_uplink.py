"""Uplink model tests (DESIGN.md §2.7): ``uplink_bw=None`` legacy parity
with the committed goldens, request/writeback routing over the contended
CC->MC uplink, byte accounting (writebacks leave the downlink), dual-queue
request protection, and the fig7 acceptance trend — daemon's advantage
grows as the uplink tightens."""
import pytest

from repro.core.sim import MovementPolicy, SimConfig, Simulator, run_one
from repro.core.sim.trace import generate
from test_multicc import GOLD, N


def test_uplink_none_bit_parity_with_goldens():
    """The legacy model (uplink_bw=None, the default) reproduces the
    pre-uplink goldens bit-for-bit for all six registered schemes — the
    request path stays folded into net_lat, writebacks stay on the
    downlink, and no uplink bytes are accounted."""
    cfg = SimConfig(link_bw_frac=0.25, uplink_bw=None)
    for key, exp in GOLD.items():
        w, s = key.split("/")
        m = run_one(w, s, cfg, seed=1, n_accesses=N)
        for name, v in exp.items():
            assert getattr(m, name) == v, (key, name)
        assert m.uplink_bytes == 0.0


def _sim(workload, scheme, cfg, *, seed=0, n=4_000):
    """A Simulator instance (not just Metrics) so tests can inspect the
    physical link byte counters."""
    per = max(1, n // cfg.n_cores)
    traces = [generate(workload, seed=seed + j, footprint=16 << 20, n=per)
              for j in range(cfg.n_cores)]
    sim = Simulator(cfg, scheme, traces, workload=workload, seed=seed)
    m = sim.run()
    return sim, m


HDR = SimConfig().header_bytes
PAGE = SimConfig().page_bytes + HDR


def test_writebacks_leave_the_downlink():
    """With the uplink modeled, dirty-page writebacks queue on the CC->MC
    uplink and are accounted as uplink bytes; the downlink metric matches
    the physical downlink byte counters exactly and carries demand pages
    only."""
    cfg = SimConfig(link_bw_frac=0.25, uplink_bw=4.0)
    sim, m = _sim("wh", "page", cfg)
    assert m.writebacks > 0
    # physical accounting: metric == sum over the per-MC link objects
    assert m.net_bytes == pytest.approx(sum(ln.bytes for ln in sim.links))
    assert m.uplink_bytes == pytest.approx(
        sum(up.bytes for up in sim.uplinks))
    # downlink carries demand pages only; uplink carries one request packet
    # per page migration plus the (uncompressed, for 'page') writebacks
    assert m.net_bytes == pytest.approx(m.pages_moved * PAGE)
    assert m.uplink_bytes == pytest.approx(
        m.pages_moved * HDR + m.writebacks * PAGE)


def test_legacy_writebacks_steal_downlink():
    """The legacy model keeps the historical (buggy) accounting the uplink
    fixes: writebacks ride the downlink and its byte metric includes
    them."""
    cfg = SimConfig(link_bw_frac=0.25)
    sim, m = _sim("wh", "page", cfg)
    assert m.writebacks > 0
    assert m.uplink_bytes == 0.0 and sim.uplinks is None
    assert m.net_bytes == pytest.approx(sum(ln.bytes for ln in sim.links))
    assert m.net_bytes == pytest.approx(
        (m.pages_moved + m.writebacks) * PAGE)


def test_tight_uplink_page_degrades_more_than_daemon():
    """Write-heavy traffic on a tight FIFO uplink head-of-line blocks the
    page scheme's request packets behind 4 KiB writebacks; daemon's
    dual-queue uplink keeps requests on a protected class, so the page
    scheme's slowdown (vs its own legacy run) exceeds daemon's."""
    base = SimConfig(link_bw_frac=0.25)
    tight = base.with_(uplink_bw=1.0)
    slow = {}
    for s in ("page", "daemon"):
        legacy = run_one("wh", s, base, n_accesses=4_000).cycles
        up = run_one("wh", s, tight, n_accesses=4_000).cycles
        slow[s] = up / legacy
    assert slow["page"] > slow["daemon"], slow


def test_dual_uplink_protects_requests_vs_fifo():
    """The uplink policy component in isolation: the same daemon
    composition with a FIFO uplink is strictly slower under tight
    write-heavy uplink contention than with the dual-queue uplink."""
    from repro.core.sim import get_policy

    cfg = SimConfig(link_bw_frac=0.25, uplink_bw=1.0)
    daemon = get_policy("daemon")
    assert daemon.uplink_partitioning == "dual"
    fifo_up = daemon.with_(name="daemon_upfifo", uplink="fifo")
    dual = run_one("wh", daemon, cfg, n_accesses=4_000).cycles
    fifo = run_one("wh", fifo_up, cfg, n_accesses=4_000).cycles
    assert dual < fifo, (dual, fifo)


def test_daemon_advantage_grows_as_uplink_tightens():
    """The fig7 acceptance trend at one representative cell: daemon-vs-page
    speedup strictly increases as uplink_bw drops from 1.0x to 0.25x of
    link_bw on a write-heavy multi-CC system."""
    prev = 0.0
    for frac in (1.0, 0.5, 0.25):
        cfg = SimConfig(link_bw_frac=0.25, n_ccs=4)
        cfg = cfg.with_(uplink_bw=cfg.link_bw * frac)
        p = run_one("wh", "page", cfg, n_accesses=4_000)
        d = run_one("wh", "daemon", cfg, n_accesses=4_000)
        ratio = p.cycles / d.cycles
        assert ratio > prev, (frac, ratio, prev)
        prev = ratio


def test_writeback_compression_keys_off_uplink_backlog():
    """Daemon writebacks compress when the uplink is backlogged: the
    uplink byte total falls strictly below the uncompressed accounting
    identity (requests are one header per line/page movement)."""
    cfg = SimConfig(link_bw_frac=0.25, uplink_bw=0.5)
    _, m = _sim("wh", "daemon", cfg)
    assert m.writebacks > 0
    uncompressed = (m.lines_moved + m.pages_moved) * HDR + m.writebacks * PAGE
    assert m.uplink_bytes < uncompressed
    assert m.bytes_saved_compression > 0


def test_uplink_validation_fails_fast():
    with pytest.raises(ValueError, match="uplink_bw"):
        SimConfig(uplink_bw=-1.0)
    with pytest.raises(ValueError, match="writeback_share"):
        SimConfig(writeback_share=1.5)
    with pytest.raises(ValueError, match="uplink"):
        MovementPolicy(name="bad_up", uplink="bogus")
