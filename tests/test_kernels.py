"""Per-kernel validation: Pallas (interpret=True on CPU) vs ref.py oracles,
swept over shapes/dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis-or-fallback shim

from repro.kernels.block_quant import ops as bq_ops
from repro.kernels.block_quant import ref as bq_ref
from repro.kernels.block_quant.block_quant import dequantize_pallas, quantize_pallas
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.mamba_scan import selective_scan_pallas
from repro.kernels.mamba_scan.ref import selective_scan_ref

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------------
# block_quant
# --------------------------------------------------------------------------


@pytest.mark.parametrize("r,c", [(8, 128), (256, 512), (300, 256), (1, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_quant_matches_ref(r, c, dtype):
    x = (jax.random.normal(jax.random.key(r * c), (r, c), jnp.float32) * 3).astype(dtype)
    q_p, s_p = quantize_pallas(x, interpret=True)
    q_r, s_r = bq_ref.quantize_ref(x)
    # scales may differ by 1 ULP (fast-math reciprocal in the compiled path),
    # flipping exact .5 boundaries by +-1 code: require <=1 code difference
    # and <0.1% mismatching elements.
    qp, qr = np.asarray(q_p, np.int32), np.asarray(q_r, np.int32)
    assert np.abs(qp - qr).max() <= 1
    assert (qp != qr).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=1e-6)
    x_p = dequantize_pallas(q_p, s_p, jnp.float32, interpret=True)
    x_r = bq_ref.dequantize_ref(q_r, s_r, jnp.float32)
    # +-1 code -> up to one scale step apart
    np.testing.assert_allclose(
        np.asarray(x_p), np.asarray(x_r), atol=float(np.asarray(s_r).max()) * 1.01
    )


def test_block_quant_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (64, 512), jnp.float32)
    q, s = bq_ops.quantize(x)
    xr = bq_ops.dequantize(q, s)
    # absmax int8: |err| <= scale/2 = absmax/254 per block
    blocks = np.asarray(x).reshape(64, 4, 128)
    bound = np.abs(blocks).max(-1) / 254 + 1e-7
    err = np.abs(np.asarray(xr) - np.asarray(x)).reshape(64, 4, 128).max(-1)
    assert (err <= bound * 1.01).all()


def test_block_quant_zero_block():
    x = jnp.zeros((8, 256), jnp.float32)
    q, s = quantize_pallas(x, interpret=True)
    assert np.asarray(q).sum() == 0
    xr = dequantize_pallas(q, s, interpret=True)
    assert np.asarray(xr).sum() == 0


@settings(max_examples=20, deadline=None)
@given(
    r=st.integers(1, 64),
    cb=st.integers(1, 6),
    scale=st.floats(1e-3, 1e3),
)
def test_block_quant_property_roundtrip(r, cb, scale):
    """Property: round-trip error is within the absmax/254 bound for any
    shape and dynamic range."""
    c = cb * 128
    x = np.random.default_rng(r * cb).normal(size=(r, c)).astype(np.float32) * scale
    q, s = bq_ref.quantize_ref(jnp.asarray(x))
    xr = np.asarray(bq_ref.dequantize_ref(q, s))
    bound = np.abs(x.reshape(r, cb, 128)).max(-1, keepdims=True) / 254 + 1e-9
    assert (np.abs(xr - x).reshape(r, cb, 128) <= bound * 1.01 + 1e-7).all()


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


ATTN_CASES = [
    # (B, Sq, Skv, H, KVH, D, causal, window)
    (1, 128, 128, 2, 2, 64, True, 0),
    (2, 256, 256, 4, 2, 64, True, 0),  # GQA
    (1, 256, 256, 2, 1, 128, True, 128),  # SWA
    (1, 128, 256, 2, 2, 64, False, 0),  # cross-ish (non-causal, longer kv)
    (2, 128, 128, 4, 4, 32, True, 0),
]


@pytest.mark.parametrize("b,sq,skv,h,kvh,d,causal,window", ATTN_CASES)
def test_flash_attention_matches_ref(b, sq, skv, h, kvh, d, causal, window):
    ks = jax.random.split(jax.random.key(42), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, kvh, d), jnp.float32)
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, bq=128, bk=128, interpret=True
    )
    expect = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    expect = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=2e-2
    )


def test_flash_attention_matches_model_path():
    """Kernel vs the chunked-jnp production path (models.nn.attention)."""
    from repro.models import nn

    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 256, 2, 64), jnp.float32)
    out_k = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    out_m = nn.attention(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_m), atol=3e-5)


# --------------------------------------------------------------------------
# mamba selective scan
# --------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,d,n,chunk", [
    (1, 128, 256, 16, 128),
    (2, 256, 256, 16, 128),
    (1, 256, 512, 8, 64),
])
def test_mamba_scan_matches_ref(b, s, d, n, chunk):
    ks = jax.random.split(jax.random.key(s * d), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, d)) - 1.0)
    a = -jnp.exp(jax.random.normal(ks[1], (d, n)) * 0.5)
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    x = jax.random.normal(ks[4], (b, s, d))
    y_p, h_p = selective_scan_pallas(dt, a, bm, cm, x, chunk=chunk, tile_d=256, interpret=True)
    y_r, h_r = selective_scan_ref(dt, a, bm, cm, x)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r), atol=1e-4, rtol=1e-4)


def test_mamba_scan_matches_model_chunked_path():
    """Kernel oracle vs the production chunked associative scan in models."""
    from repro.models.mamba import intra_chunk_scan

    b, s, d, n = 1, 64, 32, 8
    ks = jax.random.split(jax.random.key(1), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, d)))
    a = -jnp.exp(jax.random.normal(ks[1], (d, n)) * 0.3)
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    x = jax.random.normal(ks[4], (b, s, d))
    da = jnp.exp(dt[..., None] * a)
    dbx = (dt * x)[..., None] * bm[:, :, None, :]
    h_all, h_last = intra_chunk_scan(da, dbx, jnp.zeros((b, d, n)))
    y_assoc = jnp.einsum("bsdn,bsn->bsd", h_all, cm)
    y_ref, h_ref = selective_scan_ref(dt, a, bm, cm, x)
    np.testing.assert_allclose(np.asarray(y_assoc), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h_ref), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_mamba_scan_property_decay_bounds(seed):
    """Property: with |C|<=1, |B|<=1, |x|<=1 and decay in (0,1), the state is
    bounded by dt_sum and the scan never produces non-finite values."""
    rng = np.random.default_rng(seed)
    b, s, d, n = 1, 32, 16, 4
    dt = jnp.asarray(np.abs(rng.normal(size=(b, s, d))), jnp.float32)
    a = -jnp.exp(jnp.asarray(rng.normal(size=(d, n)), jnp.float32))
    bm = jnp.asarray(rng.uniform(-1, 1, (b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.uniform(-1, 1, (b, s, n)), jnp.float32)
    x = jnp.asarray(rng.uniform(-1, 1, (b, s, d)), jnp.float32)
    y, h = selective_scan_ref(dt, a, bm, cm, x)
    assert np.isfinite(np.asarray(y)).all() and np.isfinite(np.asarray(h)).all()
