"""Memory-side resident state tests (DESIGN.md §2.13): golden bit-parity
with ``mc_capacity_pages=None``, placement-registry fail-fast at every
entry point, allocator conservation invariants, spill determinism,
hot-page promotion, batch==python parity on a capacity grid, and the
eviction-monotonicity property (hypothesis where installed, the
deterministic fallback sampler otherwise)."""
import pytest

from repro.core.sim import (
    LEGACY_PLACEMENTS,
    MemsideState,
    SimConfig,
    Sweep,
    available_placements,
    covers,
    get_placement,
    make_memside,
    register_placement,
    run_one,
    run_sweep,
    serve_one,
    uncovered_reason,
    unregister_placement,
)
from repro.core.sim.engine import mc_place
from repro.core.sim.engine_batch import BatchCell, run_batch

from conftest import given, settings, st  # hypothesis-or-fallback shim
from test_multicc import GOLD, GOLD_MCC, N

# a config whose throttled regime actually promotes: tight link, tiny
# inflight-page buffer, hot threshold low enough for repeated line
# fetches to cross it before a demand migration resets the count
PROMO_CFG = dict(link_bw_frac=0.0625, n_mcs=2, inflight_pages=4,
                 mc_capacity_pages=256, mem_hot_threshold=2)


# --------------------------------------------------------------------------
# golden bit-parity: the legacy infinite model is untouched
# --------------------------------------------------------------------------


def test_capacity_none_is_bit_identical_to_goldens():
    """Explicit ``mc_capacity_pages=None`` plus a legacy placement keeps
    every scheme bit-identical to the committed goldens (make_memside
    returns None and the engines keep their original expressions)."""
    cfg = SimConfig(link_bw_frac=0.25, mc_capacity_pages=None)
    for key, exp in GOLD.items():
        w, s = key.split("/")
        m = run_one(w, s, cfg, seed=1, n_accesses=N)
        for name, v in exp.items():
            assert getattr(m, name) == v, (key, name)
        assert (m.mc_spills, m.mc_evictions, m.mc_promotions) == (0, 0, 0)
    mcc = SimConfig(link_bw_frac=0.25, n_ccs=2, mc_capacity_pages=None)
    for key, exp in GOLD_MCC.items():
        w, s = key.split("/")
        m = run_one(w, s, mcc, seed=1, n_accesses=N)
        for name, v in exp.items():
            assert getattr(m, name) == v, (key, name)


def test_make_memside_none_iff_legacy_infinite():
    for p in LEGACY_PLACEMENTS:
        assert make_memside(4, p, None, 8, 20.0) is None
        assert make_memside(4, p, 256, 8, 20.0) is not None
    assert make_memside(4, "first_touch", None, 8, 20.0) is not None
    assert make_memside(4, "capacity_aware", None, 8, 20.0) is not None


def test_legacy_placements_match_engine_mc_place():
    """The re-registered legacy homes reproduce engine.mc_place arm for
    arm — the lock that keeps registry and golden path from drifting."""
    for mode in LEGACY_PLACEMENTS:
        home = get_placement(mode).home
        for n_mcs in (1, 2, 3, 4, 7):
            occ = [0] * n_mcs
            for page in (0, 1, 2, 63, 64, 1023, 9_999_991):
                assert home(0, page, n_mcs, occ) == \
                    mc_place(page, n_mcs, mode), (mode, n_mcs, page)


# --------------------------------------------------------------------------
# registry fail-fast at every entry point
# --------------------------------------------------------------------------


def test_registry_fail_fast_everywhere():
    with pytest.raises(KeyError, match="registered placements"):
        get_placement("bogus")
    with pytest.raises(ValueError, match="mc_interleave"):
        SimConfig(mc_interleave="bogus")
    with pytest.raises(KeyError, match="bogus"):
        Sweep(name="x", axes={"mc_interleave": ("page", "bogus")})
    with pytest.raises(ValueError, match="mc_capacity_pages"):
        SimConfig(mc_capacity_pages=0)
    with pytest.raises(ValueError, match="mem_hot_threshold"):
        SimConfig(mem_hot_threshold=0)


def test_register_unregister_roundtrip():
    with pytest.raises(ValueError, match="already registered"):
        register_placement("page")(lambda cc, page, n, occ: 0)

    @register_placement("mc0_test", allocator="static", description="t")
    def _home(cc, page, n_mcs, occ):
        return 0

    try:
        assert "mc0_test" in available_placements()
        cfg = SimConfig(mc_interleave="mc0_test", mc_capacity_pages=64)
        m = run_one("st", "daemon", cfg, seed=1, n_accesses=1000)
        assert m.accesses > 0
    finally:
        unregister_placement("mc0_test")
    assert "mc0_test" not in available_placements()
    with pytest.raises(ValueError, match="mc_interleave"):
        SimConfig(mc_interleave="mc0_test")


# --------------------------------------------------------------------------
# allocator invariants
# --------------------------------------------------------------------------


def _conservation(mem: MemsideState):
    cap = mem.capacity
    for mc in range(mem.n_mcs):
        assert mem.occ[mc] == len(mem.resid[mc])
        if cap is not None:
            assert mem.occ[mc] <= cap
    assert len(mem.table) == sum(mem.occ)
    if mem.slot is not None:
        for mc in range(mem.n_mcs):
            slots = sorted(mem.slot[k] for k in mem.resid[mc])
            assert len(set(slots)) == len(slots)  # first-fit: no aliasing
            assert all(0 <= s < cap for s in slots)


@settings(max_examples=15)
@given(seed=st.integers(0, 999), cap=st.integers(2, 12),
       n_mcs=st.integers(1, 4),
       placement=st.sampled_from(("page", "first_touch", "capacity_aware")))
def test_allocator_conservation_under_random_traffic(seed, cap, n_mcs,
                                                     placement):
    """Random touch streams never overfill a module, never alias slab
    slots, and keep table/occ/resid views consistent."""
    import numpy as np

    rng = np.random.default_rng(seed)
    mem = MemsideState(n_mcs, placement, cap, 4, 20.0)
    kinds = ("line", "line", "page", "wb")
    for _ in range(300):
        cc = int(rng.integers(0, 3))
        page = int(rng.integers(0, 8 * cap))
        mem.touch(cc, page, kinds[int(rng.integers(0, len(kinds)))])
    _conservation(mem)
    assert mem.evictions >= 0 and mem.spills >= 0


def test_spill_charges_ring_distance():
    """Once the home module is full, allocation spills to the nearest
    ring neighbour with room and every later touch of the spilled page
    pays hops x switch_lat."""
    mem = MemsideState(4, "single", 2, 8, 20.0)  # everything homes at MC 0
    assert mem.touch(0, 1, "line")[:2] == (0, 0.0)
    assert mem.touch(0, 2, "line")[:2] == (0, 0.0)
    mc, xl, _ = mem.touch(0, 3, "line")  # MC 0 full: spill to MC 1
    assert (mc, xl) == (1, 20.0)
    assert mem.spills == 1
    assert mem.touch(0, 3, "line")[:2] == (1, 20.0)  # resident now
    _conservation(mem)


def test_pool_full_evicts_coldest_at_home():
    mem = MemsideState(1, "page", 2, 100, 20.0)
    mem.touch(0, 1, "line")
    mem.touch(0, 1, "line")  # page 1 is hot (count 2)
    mem.touch(0, 2, "line")  # page 2 cold (count 1)
    mem.touch(0, 3, "line")  # pool full: evicts page 2, not page 1
    assert mem.evictions == 1
    assert mem.resident_mc(0, 1) == 0
    assert mem.resident_mc(0, 2) is None
    assert mem.resident_mc(0, 3) == 0
    _conservation(mem)


# --------------------------------------------------------------------------
# determinism + eviction monotonicity
# --------------------------------------------------------------------------


def test_spill_determinism_run_after_run():
    cfg = SimConfig(link_bw_frac=0.25, n_ccs=2, n_mcs=4,
                    mc_interleave="first_touch", mc_capacity_pages=128)
    a = run_one("pr+st", "daemon", cfg, seed=1, n_accesses=3000)
    b = run_one("pr+st", "daemon", cfg, seed=1, n_accesses=3000)
    assert a.as_dict() == b.as_dict()
    assert a.mc_spills > 0  # first_touch piles both tenants' homes


@settings(max_examples=8)
@given(seed=st.integers(0, 99), cap=st.sampled_from((64, 128, 256)),
       placement=st.sampled_from(("page", "first_touch", "capacity_aware")))
def test_eviction_count_monotone_in_capacity_pressure(seed, cap, placement):
    """Shrinking the pool 4x never reduces evictions for the same touch
    stream (the property the capacity model must keep to mean anything)."""
    import numpy as np

    def evictions(capacity):
        rng = np.random.default_rng(seed)
        mem = MemsideState(2, placement, capacity, 8, 20.0)
        for _ in range(1500):
            mem.touch(int(rng.integers(0, 2)),
                      int(rng.integers(0, 3 * cap)), "line")
        return mem.evictions

    assert evictions(cap // 4) >= evictions(cap)


def test_sim_eviction_monotone_and_counters_surface():
    cfg = dict(link_bw_frac=0.25, n_ccs=2, n_mcs=4)
    m_big = run_one("pr+st", "daemon",
                    SimConfig(mc_capacity_pages=256, **cfg),
                    seed=1, n_accesses=4000)
    m_small = run_one("pr+st", "daemon",
                      SimConfig(mc_capacity_pages=64, **cfg),
                      seed=1, n_accesses=4000)
    assert m_small.mc_evictions >= m_big.mc_evictions > 0
    assert m_big.as_dict()["mc_evictions"] == m_big.mc_evictions


def test_hot_page_promotion_fires_in_throttled_regime():
    """Hotness accumulates exactly where demand migration is throttled;
    the promotion path must fire there (a gate on the controller's
    issue_page signal would never fire by construction)."""
    m = run_one("pr", "daemon", SimConfig(**PROMO_CFG), seed=1,
                n_accesses=4000)
    assert m.mc_promotions > 0
    # the throttle-free composition keeps resetting hotness with demand
    # migrations, so it never promotes
    m2 = run_one("pr", "both", SimConfig(**PROMO_CFG), seed=1,
                 n_accesses=4000)
    assert m2.mc_promotions == 0


# --------------------------------------------------------------------------
# batch==python parity on the capacity grid
# --------------------------------------------------------------------------


def test_capacity_cells_are_batch_covered():
    assert covers(SimConfig(mc_capacity_pages=128), "daemon")
    assert covers(SimConfig(mc_interleave="capacity_aware"), "daemon")
    assert uncovered_reason(SimConfig(mc_capacity_pages=128), "daemon") \
        is None


def test_uncovered_reason_names_the_config_field():
    assert "serving_router" in uncovered_reason(
        SimConfig(serving_router="round_robin", n_ccs=2), "daemon")
    assert "topology" in uncovered_reason(
        SimConfig(topology="two_tier"), "daemon")
    assert "per-CC" in uncovered_reason(SimConfig(), ["page", "daemon"])
    cell = BatchCell("pr", "daemon", SimConfig(topology="two_tier"))
    with pytest.raises(ValueError, match="topology="):
        run_batch([cell])


def test_batch_python_parity_on_capacity_grid():
    """Both engines drive the same MemsideState at the same event points,
    so every §2.13 cell is bit-identical across engines."""
    cells = []
    for scheme in ("page", "daemon"):
        for place in ("page", "first_touch", "capacity_aware"):
            for cap in (None, 128):
                cfg = SimConfig(link_bw_frac=0.25, n_ccs=2, n_mcs=4,
                                mc_interleave=place, mc_capacity_pages=cap)
                cells.append(BatchCell("pr+st", scheme, cfg, seed=1,
                                       n_accesses=2000))
    cells.append(BatchCell("pr", "daemon", SimConfig(**PROMO_CFG), seed=1,
                           n_accesses=2000))  # the promotion-heavy cell
    br = run_batch(cells)
    for cell, bm in zip(cells, br.metrics):
        om = run_one(cell.workload, cell.scheme, cell.cfg, seed=cell.seed,
                     n_accesses=cell.n_accesses)
        assert om.as_dict() == bm.as_dict(), cell


def test_sweep_batch_engine_matches_python_on_capacity_axes():
    sw = Sweep(name="mem_parity",
               axes={"workload": ("pr",), "scheme": ("page", "daemon"),
                     "mc_interleave": ("page", "capacity_aware"),
                     "mc_capacity_pages": (None, 128)},
               base=SimConfig(link_bw_frac=0.25, n_mcs=4),
               n_accesses=2000, base_seed=1)
    py = run_sweep(sw, engine="python")
    ba = run_sweep(sw, engine="batch")
    for a, b in zip(py.rows, ba.rows):
        assert a.axes == b.axes
        assert a.metrics.as_dict() == b.metrics.as_dict()


# --------------------------------------------------------------------------
# serving: multi-tenant capacity contention
# --------------------------------------------------------------------------


def test_serving_tenants_contend_for_capacity():
    """A finite pool under the serving layer shows capacity churn without
    any serving-layer code being capacity-aware, and stays deterministic."""
    cfg = SimConfig(
        n_ccs=2, n_mcs=1, link_bw_frac=0.5, serving_router="round_robin",
        n_requests=6, offered_load=40.0,
        prefill_workload="st", decode_workload="st",
        prefill_accesses=128, decode_steps=2, decode_accesses=64,
        mc_capacity_pages=2, mem_hot_threshold=4)
    a = serve_one(cfg, "daemon", seed=7)
    b = serve_one(cfg, "daemon", seed=7)
    assert a.as_dict() == b.as_dict()
    assert a.mc_evictions > 0
