"""Multi-CC system model tests (DESIGN.md §2.5): n_ccs=1 bit-parity with
the legacy single-CC engine, determinism under the process-pool sweep,
per-CC metric rollups, and the contention regression — the page scheme's
slowdown grows with the CC count while DaeMon's line latency stays bounded
behind the reserved line share."""
import pytest

from repro.core.sim import (
    SimConfig,
    Sweep,
    get_policy,
    run_one,
    run_sweep,
    simulate,
)
from repro.core.sim.trace import generate

N = 6_000


# Golden metrics captured from the single-CC engine BEFORE the multi-CC
# refactor (run_one(w, s, SimConfig(link_bw_frac=0.25), seed=1,
# n_accesses=6000) at commit 9d8f995).  n_ccs=1 must reproduce these
# bit-for-bit for all six schemes — the invariant that keeps every
# committed BENCH result valid.
GOLD = {
    "pr/local": {"cycles": 54630.0, "net_bytes": 0.0,
                 "miss_latency_sum": 1795500.0, "pages_moved": 0,
                 "lines_moved": 0, "local_hits": 5985, "remote_misses": 0},
    "pr/page": {"cycles": 2166976.0, "net_bytes": 17336192.0,
                "miss_latency_sum": 118241362.0, "pages_moved": 4079,
                "lines_moved": 0, "local_hits": 1651, "remote_misses": 4334},
    "pr/page_free": {"cycles": 54630.0, "net_bytes": 555120.0,
                     "miss_latency_sum": 1795500.0, "pages_moved": 4140,
                     "lines_moved": 0, "local_hits": 1845,
                     "remote_misses": 4140},
    "pr/cacheline": {"cycles": 503855.0, "net_bytes": 467600.0,
                     "miss_latency_sum": 37422190.0, "pages_moved": 0,
                     "lines_moved": 5845, "local_hits": 0,
                     "remote_misses": 5985},
    "pr/both": {"cycles": 2210266.0, "net_bytes": 17681232.0,
                "miss_latency_sum": 123816671.0, "pages_moved": 4079,
                "lines_moved": 4313, "local_hits": 1652,
                "remote_misses": 4333},
    "pr/daemon": {"cycles": 503197.3333333333, "net_bytes": 1497893.3593311892,
                  "miss_latency_sum": 31699555.11210921, "pages_moved": 731,
                  "lines_moved": 5054, "local_hits": 855,
                  "remote_misses": 5130},
    "st/local": {"cycles": 49537.0, "net_bytes": 0.0,
                 "miss_latency_sum": 1800000.0, "pages_moved": 0,
                 "lines_moved": 0, "local_hits": 6000, "remote_misses": 0},
    "st/page": {"cycles": 206120.0, "net_bytes": 180928.0,
                "miss_latency_sum": 13013075.0, "pages_moved": 24,
                "lines_moved": 0, "local_hits": 4275, "remote_misses": 1725},
    "st/page_free": {"cycles": 49537.0, "net_bytes": 82240.0,
                     "miss_latency_sum": 1800000.0, "pages_moved": 24,
                     "lines_moved": 0, "local_hits": 5976,
                     "remote_misses": 24},
    "st/cacheline": {"cycles": 489968.0, "net_bytes": 120000.0,
                     "miss_latency_sum": 34830533.0, "pages_moved": 0,
                     "lines_moved": 1500, "local_hits": 0,
                     "remote_misses": 6000},
    "st/both": {"cycles": 204510.0, "net_bytes": 219968.0,
                "miss_latency_sum": 9947746.0, "pages_moved": 24,
                "lines_moved": 488, "local_hits": 4183,
                "remote_misses": 1817},
    "st/daemon": {"cycles": 205603.0, "net_bytes": 182848.0,
                  "miss_latency_sum": 12995809.666666666, "pages_moved": 24,
                  "lines_moved": 24, "local_hits": 4183,
                  "remote_misses": 1817},
}


# Golden metrics for the multi-CC engine captured BEFORE the policy-registry
# refactor (run_one("pr+st", s, SimConfig(link_bw_frac=0.25, n_ccs=2),
# seed=1, n_accesses=6000) at commit 886acec).  The six legacy schemes,
# re-expressed as registered policy compositions, must reproduce these
# bit-for-bit too (the n_ccs>1 half of the parity acceptance).
#
# NOTE (per-CC compression RNG): the compression-ratio stream is now seeded
# per (seed, cc.idx) — CC 0 keeps the legacy stream — instead of one shared
# stream drawn in global event order.  These goldens did NOT change: in the
# pr+st daemon cell only CC 0 (pr) ever engages compression (st never backs
# its page buffer past PAGE_FAST), so the legacy shared stream was already
# effectively CC 0's.  Mixes where several CCs compress (e.g. fig5's
# dr+st+pr+ml at n_ccs>=4) DO shift — BENCH_sim.json was regenerated in the
# same change.
GOLD_MCC = {
    "pr+st/local": {"cycles": 54630.0, "net_bytes": 0.0,
                    "miss_latency_sum": 3595500.0, "pages_moved": 0,
                    "lines_moved": 0, "local_hits": 11985,
                    "remote_misses": 0},
    "pr+st/page": {"cycles": 2189592.0, "net_bytes": 17517120.0,
                   "miss_latency_sum": 133378600.0, "pages_moved": 4103,
                   "lines_moved": 0, "local_hits": 5951,
                   "remote_misses": 6034},
    "pr+st/page_free": {"cycles": 54630.0, "net_bytes": 637360.0,
                        "miss_latency_sum": 3595500.0, "pages_moved": 4164,
                        "lines_moved": 0, "local_hits": 7821,
                        "remote_misses": 4164},
    "pr+st/cacheline": {"cycles": 504104.0, "net_bytes": 587440.0,
                        "miss_latency_sum": 72146666.0, "pages_moved": 0,
                        "lines_moved": 7343, "local_hits": 0,
                        "remote_misses": 11985},
    "pr+st/both": {"cycles": 2237712.0, "net_bytes": 17900800.0,
                   "miss_latency_sum": 136801648.0, "pages_moved": 4103,
                   "lines_moved": 4796, "local_hits": 5854,
                   "remote_misses": 6131},
    "pr+st/daemon": {"cycles": 500026.1135329509,
                     "net_bytes": 1674789.362959711,
                     "miss_latency_sum": 45104614.51773566,
                     "pages_moved": 749, "lines_moved": 5084,
                     "local_hits": 5056, "remote_misses": 6929},
}


def test_nccs1_bit_parity_with_legacy_engine():
    """n_ccs=1 reproduces the pre-refactor single-CC metrics bit-for-bit
    across all six schemes (explicit n_ccs=1 and the default both)."""
    for key, exp in GOLD.items():
        w, s = key.split("/")
        for cfg in (SimConfig(link_bw_frac=0.25),
                    SimConfig(link_bw_frac=0.25, n_ccs=1)):
            m = run_one(w, s, cfg, seed=1, n_accesses=N)
            for name, v in exp.items():
                assert getattr(m, name) == v, (key, name)
            assert m.per_cc == []  # single-CC: the aggregate IS the CC


def test_multicc_bit_parity_with_legacy_engine():
    """n_ccs=2 reproduces the pre-policy-registry multi-CC metrics
    bit-for-bit across all six schemes."""
    cfg = SimConfig(link_bw_frac=0.25, n_ccs=2)
    for key, exp in GOLD_MCC.items():
        w, s = key.split("/")
        m = run_one(w, s, cfg, seed=1, n_accesses=N)
        for name, v in exp.items():
            assert getattr(m, name) == v, (key, name)
        assert len(m.per_cc) == 2


def test_policy_objects_match_scheme_strings():
    """A scheme string and its registered MovementPolicy composition are the
    same simulation: run_one accepts either and produces identical metrics
    (the composition IS the scheme, not an approximation of it)."""
    cfg = SimConfig(link_bw_frac=0.25)
    for key, exp in GOLD.items():
        w, s = key.split("/")
        m = run_one(w, get_policy(s), cfg, seed=1, n_accesses=N)
        for name, v in exp.items():
            assert getattr(m, name) == v, (key, name)
        assert m.scheme == s  # metrics keep the registered policy name


def test_multicc_trace_group_shape_is_validated():
    traces = [generate("pr", seed=0, footprint=1 << 20, n=200)]
    with pytest.raises(ValueError, match="n_ccs"):
        simulate(SimConfig(n_ccs=2), "page", traces, workload="pr")


def test_multicc_per_cc_rollup_consistent():
    """Aggregate counters are the sum of per_cc; cycles is the makespan;
    the '+' mix assigns workloads round-robin across CCs."""
    m = run_one("pr+st", "daemon", SimConfig(n_ccs=4, link_bw_frac=0.25),
                n_accesses=4_000)
    assert [d["workload"] for d in m.per_cc] == ["pr", "st", "pr", "st"]
    assert [d["cc"] for d in m.per_cc] == [0, 1, 2, 3]
    for key in ("accesses", "llc_hits", "local_hits", "remote_misses",
                "net_bytes", "uplink_bytes", "pages_moved", "lines_moved",
                "writebacks", "miss_latency_sum", "stall_episodes"):
        assert sum(d[key] for d in m.per_cc) == pytest.approx(
            getattr(m, key)), key
    assert m.cycles == max(d["cycles"] for d in m.per_cc)


def test_multicc_sweep_parallel_equals_serial():
    """Multi-CC cells keep the sweep-engine determinism guarantee: a
    process-pool run is cell-for-cell identical to the serial run."""
    sw = Sweep(
        name="mcc",
        axes={"workload": ("pr+st",), "n_ccs": (2, 4),
              "scheme": ("page", "daemon")},
        base=SimConfig(link_bw_frac=0.25),
        n_accesses=3_000,
    )
    serial = run_sweep(sw, workers=1)
    par = run_sweep(sw, workers=2)
    assert [r.axes for r in serial.rows] == [r.axes for r in par.rows]
    assert [r.metrics.as_dict() for r in serial.rows] == \
           [r.metrics.as_dict() for r in par.rows]


def test_contention_page_degrades_daemon_lines_bounded():
    """The paper's multi-CC contention story: stacking CCs on the shared MC
    downlink slows the page scheme superlinearly (each CC's critical lines
    wait behind ALL CCs' page bursts), while DaeMon's reserved line share
    keeps its average access cost bounded."""
    cfg = SimConfig(link_bw_frac=0.25)
    page_slow, daemon_cost = {}, {}
    for n in (1, 2, 4):
        c = cfg.with_(n_ccs=n)
        page_slow[n] = run_one("pr", "page", c, n_accesses=4_000).cycles
        daemon_cost[n] = run_one("pr", "daemon", c, n_accesses=4_000).avg_access_cost
    # page-scheme slowdown grows with every added CC
    assert page_slow[2] > page_slow[1] * 1.2, page_slow
    assert page_slow[4] > page_slow[2] * 1.2, page_slow
    # daemon's average miss latency stays bounded (not the page scheme's
    # multiplicative collapse) thanks to the fixed-rate line share
    assert daemon_cost[4] < daemon_cost[1] * 3.0, daemon_cost
    page_cost_1 = run_one("pr", "page", cfg, n_accesses=4_000).avg_access_cost
    page_cost_4 = run_one("pr", "page", cfg.with_(n_ccs=4),
                          n_accesses=4_000).avg_access_cost
    assert page_cost_4 / page_cost_1 > daemon_cost[4] / daemon_cost[1]


def test_daemon_advantage_grows_with_ccs():
    """Acceptance: daemon-vs-page speedup increases monotonically in n_ccs
    (the fig5_scalability headline) on a representative mix."""
    prev = 0.0
    for n in (1, 2, 4, 8):
        cfg = SimConfig(n_ccs=n, link_bw_frac=0.25)
        p = run_one("pr+st", "page", cfg, n_accesses=1_500)
        d = run_one("pr+st", "daemon", cfg, n_accesses=1_500)
        ratio = p.cycles / d.cycles
        assert ratio > prev, (n, ratio, prev)
        prev = ratio
