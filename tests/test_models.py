"""Per-arch smoke tests: REDUCED same-family configs, one forward/train step
and one prefill+decode step on CPU; asserts output shapes and finiteness.
The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import model as M
from repro.models import nn

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 64


def make_batch(cfg, rng):
    if cfg.family == "vlm":
        p = cfg.num_prefix_tokens
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - p)), jnp.int32),
            "patches": jnp.asarray(rng.normal(size=(B, p, cfg.d_model)), jnp.bfloat16),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - p)), jnp.int32),
        }
    if cfg.family == "audio":
        return {
            "frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    specs = M.model_specs(cfg)
    params = nn.init_params(specs, jax.random.key(0))
    batch = make_batch(cfg, rng)

    loss, grads = jax.jit(
        lambda p, b: jax.value_and_grad(lambda pp: M.loss_fn(cfg, pp, b)[0])(p)
    )(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss {loss}"
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)), f"{arch}: non-finite grad norm"
    assert float(gnorm) > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch, rng):
    cfg = get_config(arch).reduced()
    specs = M.model_specs(cfg)
    params = nn.init_params(specs, jax.random.key(1))
    batch = make_batch(cfg, rng)
    batch.pop("labels")

    logits, cache = jax.jit(lambda p, b: M.prefill(cfg, p, b))(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: prefill NaN"

    # one decode step appended at position S (cache must have a free slot:
    # decode caches in these tests are sized by prefill seq len, so write at
    # the ring slot / last slot as the model family dictates)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos = jnp.asarray(S - 1, jnp.int32)  # overwrite last slot: shape-safe
    logits2, cache2 = jax.jit(lambda p, c, t, q: M.decode_step(cfg, p, c, t, q))(
        params, cache, tok, pos
    )
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), f"{arch}: decode NaN"


@pytest.mark.parametrize("arch", ARCHS)
def test_live_cells_and_counts(arch):
    cfg = get_config(arch)
    cells = cfg.live_cells()
    names = [c.name for c in cells]
    assert "train_4k" in names and "decode_32k" in names
    if arch in ("falcon-mamba-7b", "zamba2-1.2b", "h2o-danube-1.8b"):
        assert "long_500k" in names
    else:
        assert "long_500k" not in names
    n = M.param_count(cfg)
    assert n > 0
    if cfg.family == "moe":
        assert M.param_count(cfg, active_only=True) < n
