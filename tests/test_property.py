"""Cross-cutting property tests on system invariants (hypothesis where
installed, the deterministic conftest fallback sampler otherwise)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import given, settings, st  # hypothesis-or-fallback shim

from repro.core.sim.engine import LRU, DualQueueLink, Engine
from repro.optim import schedule
from repro.runtime.elastic import plan_mesh

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=25, deadline=None)
@given(cap=st.integers(1, 32), n=st.integers(1, 200), seed=st.integers(0, 99))
def test_lru_never_exceeds_capacity_and_hits_recent(cap, n, seed):
    rng = np.random.default_rng(seed)
    lru = LRU(cap)
    for tag in rng.integers(0, 50, n):
        if not lru.access(int(tag)):
            lru.insert(int(tag))
        assert len(lru.d) <= cap
    last = int(rng.integers(0, 50))
    lru.insert(last)
    assert lru.access(last)  # most-recent always resident


@settings(max_examples=15, deadline=None)
@given(
    sizes=st.lists(
        st.tuples(st.sampled_from(["line", "page"]), st.floats(8, 4096)),
        min_size=1, max_size=30,
    ),
    bw=st.floats(1.0, 64.0),
    share=st.floats(0.1, 0.9),
)
def test_dual_queue_link_conserves_all_transfers(sizes, bw, share):
    """Every transfer enqueued on the fluid dual-queue link completes exactly
    once, regardless of interleaving (the deadlock class fixed in §sim)."""
    eng = Engine()
    link = DualQueueLink(eng, bw, share)
    done = []
    t = 0.0
    for i, (cls, size) in enumerate(sizes):
        t += (i % 3) * 0.5  # staggered arrivals
        eng.at(t, lambda tt, s=size, c=cls, j=i: link.send(tt, s, lambda a: done.append(j), c))
    eng.run()
    assert sorted(done) == list(range(len(sizes)))


@settings(max_examples=20, deadline=None)
@given(
    total=st.integers(10, 5000),
    peak=st.floats(1e-5, 1.0),
)
def test_schedules_bounded_and_nonnegative(total, peak):
    warm = max(1, total // 10)
    for name in ("wsd", "cosine"):
        f = schedule.make(name, peak_lr=peak, total_steps=total, warmup_steps=warm)
        for s in (0, warm, total // 2, total - 1, total):
            v = float(f(s))
            assert 0.0 <= v <= peak * 1.0001, (name, s, v)


@settings(max_examples=30, deadline=None)
@given(chips=st.integers(16, 4096), batch=st.sampled_from([64, 128, 256, 512]))
def test_plan_mesh_invariants(chips, batch):
    plan = plan_mesh(chips, model_degree=16, global_batch=batch)
    assert plan.used_chips + plan.spare_chips == chips
    assert plan.used_chips == plan.pods * plan.data * plan.model
    assert plan.model == 16
    assert batch % (plan.data * plan.pods) == 0 or plan.data == 1


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), b=st.integers(1, 3), s=st.sampled_from([16, 32]))
def test_chunked_ce_matches_full_ce(seed, b, s):
    """The chunked cross-entropy equals a direct full-logits computation."""
    from repro.configs import get_config
    from repro.models import model as M
    from repro.models import nn

    cfg = get_config("h2o-danube-1.8b").reduced()
    params = nn.init_params(M.model_specs(cfg), jax.random.key(seed))
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    loss, metrics = M.loss_fn(cfg, params, batch, training=False, z_weight=0.0)
    hidden, _, _ = M.forward_hidden(cfg, params, batch, training=False)
    logits = M.logits_at(cfg, params, hidden)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["labels"][..., None], axis=-1)[..., 0]
    direct = jnp.mean(logz - ll)
    np.testing.assert_allclose(float(metrics["ce"]), float(direct), rtol=2e-5)
