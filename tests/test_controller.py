"""MovementController layer tests (DESIGN.md §2.12): fixed-controller
bit-parity with the pre-refactor goldens, batch-vs-python parity on
controller grids, registry fail-fast across every entry point, the
adaptive controller's monotone-backoff property, and the PAGE_FAST
drift-lock (the threshold lives in controller.py and nowhere else)."""
import pytest

from conftest import given, settings, st  # hypothesis-or-fallback shim

from repro.core.sim import (
    MovementPolicy,
    SimConfig,
    Simulator,
    Sweep,
    available_controllers,
    get_controller,
    get_policy,
    make_controller,
    register_controller,
    resolve_controller,
    run_one,
    run_sweep,
    unregister_controller,
)
from repro.core.sim import controller as ctrl_mod
from repro.core.sim import engine as engine_mod
from repro.core.sim.controller import (
    PAGE_FAST,
    AdaptiveController,
    Decision,
    FixedController,
    MovementController,
    Observation,
    selection_races_line,
)
from test_multicc import GOLD, GOLD_MCC, N


# --------------------------------------------------------------------------
# fixed controller: bit-parity with the pre-refactor goldens
# --------------------------------------------------------------------------


def test_explicit_fixed_controller_reproduces_goldens():
    """cfg.controller='fixed' is the same simulation as the default (None):
    every pre-refactor single-CC golden reproduces bit-for-bit across all
    six schemes."""
    cfg = SimConfig(link_bw_frac=0.25, controller="fixed")
    for key, exp in GOLD.items():
        w, s = key.split("/")
        m = run_one(w, s, cfg, seed=1, n_accesses=N)
        for name, v in exp.items():
            assert getattr(m, name) == v, (key, name)


def test_explicit_fixed_controller_reproduces_multicc_goldens():
    cfg = SimConfig(link_bw_frac=0.25, n_ccs=2, controller="fixed")
    for key, exp in GOLD_MCC.items():
        w, s = key.split("/")
        m = run_one(w, s, cfg, seed=1, n_accesses=N)
        for name, v in exp.items():
            assert getattr(m, name) == v, (key, name)


def test_policy_controller_component_overrides_config():
    """MovementPolicy.controller beats SimConfig.controller (the serving
    per-pool override path); both routes to 'fixed' match the default."""
    cfg = SimConfig(link_bw_frac=0.25)
    base = run_one("pr", "daemon", cfg, seed=1, n_accesses=2000)
    pol = get_policy("daemon").with_(controller="fixed")
    via_policy = run_one("pr", pol, cfg.with_(controller="adaptive"),
                         seed=1, n_accesses=2000)
    assert base.cycles == via_policy.cycles
    assert base.net_bytes == via_policy.net_bytes


# --------------------------------------------------------------------------
# engine parity: the batch core and the oracle agree under every controller
# --------------------------------------------------------------------------


def test_batch_python_parity_on_controller_grid():
    """Controller cells stay batch-covered and bit-identical between the
    lockstep batch core and the per-cell oracle."""
    from repro.core.sim import covers

    for ctrl in ("adaptive", "tuned"):
        cfg = SimConfig(link_bw_frac=0.25, controller=ctrl)
        assert covers(cfg, "daemon")
        sw = {
            eng: Sweep(name="ctrl_parity", engine=eng, base=cfg,
                       n_accesses=2000,
                       axes={"scheme": ("daemon", "page", "both"),
                             "workload": ("pr", "st"), "seed": (1,)})
            for eng in ("python", "batch")
        }
        a = run_sweep(sw["python"])
        b = run_sweep(sw["batch"])
        for ra, rb in zip(a.rows, b.rows):
            assert ra.axes == rb.axes
            assert ra.metrics.as_dict() == rb.metrics.as_dict(), \
                (ctrl, ra.axes)


def test_batch_python_parity_multicc_adaptive():
    cfg = SimConfig(link_bw_frac=0.25, n_ccs=2, controller="adaptive")
    mk = lambda eng: Sweep(name="ctrl_parity_mcc", engine=eng, base=cfg,
                           n_accesses=2000,
                           axes={"scheme": ("daemon",),
                                 "workload": ("pr+st",), "seed": (1,)})
    a = run_sweep(mk("python"))
    b = run_sweep(mk("batch"))
    assert a.rows[0].metrics.as_dict() == b.rows[0].metrics.as_dict()


# --------------------------------------------------------------------------
# registry fail-fast: every entry point rejects unknown controller names
# --------------------------------------------------------------------------


def test_get_controller_unknown_lists_choices():
    with pytest.raises(KeyError, match="adaptive"):
        get_controller("nope")


def test_config_validates_controller_names():
    with pytest.raises(ValueError, match="controller"):
        SimConfig(controller="nope")
    with pytest.raises(ValueError, match="controller"):
        SimConfig(serving_prefill_controller="nope")
    with pytest.raises(ValueError, match="controller"):
        SimConfig(serving_decode_controller="nope")


def test_policy_validates_controller_component():
    with pytest.raises(ValueError, match="controller"):
        get_policy("daemon").with_(controller="nope")


def test_sweep_validates_controller_axis():
    with pytest.raises(KeyError, match="nope"):
        Sweep(name="bad", axes={"scheme": ("daemon",),
                                "workload": ("pr",),
                                "controller": ("fixed", "nope")})


def test_register_controller_rejects_duplicates_and_unnamed():
    class Dup(FixedController):
        name = "fixed"

    with pytest.raises(ValueError, match="already registered"):
        register_controller(Dup)

    class NoName(MovementController):
        pass

    with pytest.raises(ValueError, match="no name"):
        register_controller(NoName)


def test_register_unregister_roundtrip():
    @register_controller
    class Temp(FixedController):
        name = "temp_ctrl"
        description = "test-only"

    try:
        assert "temp_ctrl" in available_controllers()
        m = run_one("pr", "daemon",
                    SimConfig(link_bw_frac=0.25, controller="temp_ctrl"),
                    seed=1, n_accesses=2000)
        base = run_one("pr", "daemon", SimConfig(link_bw_frac=0.25),
                       seed=1, n_accesses=2000)
        assert m.cycles == base.cycles  # Temp decides exactly like fixed
    finally:
        unregister_controller("temp_ctrl")
    assert "temp_ctrl" not in available_controllers()


def test_resolve_controller_precedence():
    cfg = SimConfig(controller="adaptive")
    pol = get_policy("daemon")
    assert resolve_controller(pol, cfg) == "adaptive"
    assert resolve_controller(pol.with_(controller="tuned"), cfg) == "tuned"
    assert resolve_controller(pol, SimConfig()) == "fixed"


def test_serving_pool_controller_overrides_need_disjoint_pools():
    from repro.core.sim import ServingScheduler

    cfg = SimConfig(n_ccs=2, serving_router="least_loaded",
                    serving_prefill_controller="adaptive")
    with pytest.raises(ValueError, match="disjoint pools"):
        ServingScheduler(cfg, "daemon", seed=0)


def test_serving_pool_controller_overrides_apply():
    from repro.core.sim import ServingScheduler

    cfg = SimConfig(n_ccs=2, serving_router="disagg_prefill",
                    serving_prefill_controller="adaptive",
                    serving_decode_controller="tuned",
                    n_requests=4, prefill_accesses=128, decode_steps=2,
                    decode_accesses=64)
    sched = ServingScheduler(cfg, "daemon", seed=0)
    kinds = {type(cc.ctrl).name for cc in sched.sim.ccs}
    assert kinds == {"adaptive", "tuned"}


# --------------------------------------------------------------------------
# PAGE_FAST drift-lock: one source of truth
# --------------------------------------------------------------------------


def test_page_fast_single_source_of_truth():
    """The selection threshold lives in controller.py; engine.py re-exports
    the same object and the Simulator class no longer carries its own
    copy (the pre-refactor duplicate)."""
    assert PAGE_FAST == 0.3
    assert engine_mod.PAGE_FAST is ctrl_mod.PAGE_FAST
    assert engine_mod.selection_races_line is ctrl_mod.selection_races_line
    assert "PAGE_FAST" not in Simulator.__dict__


# --------------------------------------------------------------------------
# adaptive controller properties
# --------------------------------------------------------------------------


@settings(max_examples=40)
@given(lu=st.floats(0.0, 1.5), pu=st.floats(0.0, 1.5),
       density=st.floats(0.0, 1.0), backlog=st.floats(0.0, 1 << 16))
def test_adaptive_race_is_subset_of_fixed(lu, pu, density, backlog):
    """Adaptive only ever *suppresses* races: whenever adaptive races a
    line, fixed would have raced it too, and every other decision field
    matches fixed exactly (throttle/compression are untouched)."""
    cfg = SimConfig()
    fx = make_controller("fixed", cfg)
    ad = make_controller("adaptive", cfg)
    ad.density = density
    obs = Observation(0.0, lu, pu, backlog)
    df, da = fx.decide(obs), ad.decide(obs)
    assert isinstance(da, Decision)
    if da.race_line:
        assert df.race_line
    assert da.issue_line == df.issue_line
    assert da.issue_page == df.issue_page
    assert da.compress == df.compress
    assert da.compress_writeback == df.compress_writeback


@settings(max_examples=20)
@given(lu=st.floats(0.0, 0.99), pu=st.floats(0.31, 1.0),
       d_lo=st.floats(0.0, 1.0), d_hi=st.floats(0.0, 1.0))
def test_adaptive_backoff_is_monotone_in_density(lu, pu, d_lo, d_hi):
    """Raising the coalesce density never turns racing back ON: the
    backoff is monotone (no flapping around the threshold from above)."""
    d_lo, d_hi = min(d_lo, d_hi), max(d_lo, d_hi)
    cfg = SimConfig()
    obs = Observation(0.0, lu, pu, 0.0)
    ad = make_controller("adaptive", cfg)
    ad.density = d_lo
    race_lo = ad.decide(obs).race_line
    ad.density = d_hi
    race_hi = ad.decide(obs).race_line
    assert race_hi <= race_lo
    assert selection_races_line(lu, pu)  # the fixed rule always races here


def test_adaptive_density_ewma_converges():
    ad = AdaptiveController(SimConfig())
    for _ in range(600):
        ad.observe_miss(True)
    assert ad.density > AdaptiveController.race_backoff
    obs = Observation(0.0, 0.5, 0.5, 0.0)
    assert not ad.decide(obs).race_line
    for _ in range(600):
        ad.observe_miss(False)
    assert ad.density < AdaptiveController.race_backoff
    assert ad.decide(obs).race_line


def test_adaptive_identical_to_fixed_on_sparse_synthetics():
    """On a sparse synthetic source the density never crosses the backoff,
    so 'adaptive' is decision-identical to 'fixed' — the guardrail that
    keeps the paper's headline geomeans intact."""
    base = run_one("pr", "daemon", SimConfig(link_bw_frac=0.25),
                   seed=1, n_accesses=4000)
    ad = run_one("pr", "daemon",
                 SimConfig(link_bw_frac=0.25, controller="adaptive"),
                 seed=1, n_accesses=4000)
    assert ad.cycles == base.cycles
    assert ad.net_bytes == base.net_bytes


def test_tuned_thresholds_substitute_into_fixed_formulas():
    cfg = SimConfig()
    tc = make_controller("tuned", cfg, "st")
    pf, th = ctrl_mod.TUNED_THRESHOLDS["st"]
    assert tc.thresholds() == {"page_fast": pf, "throttle_hi": th}
    d = tc.decide(Observation(0.0, 0.5, (pf + th) / 2, 0.0))
    assert d.race_line and d.compress and d.issue_page == ((pf + th) / 2 < th)
    # unknown workloads fall back to the fixed constants
    fb = make_controller("tuned", cfg, "no_such_workload")
    assert fb.thresholds() == {"page_fast": PAGE_FAST,
                               "throttle_hi": cfg.page_throttle_hi}


def test_controller_policy_component_listed():
    """MovementPolicy.components() exposes the controller slot so
    run.py --list and policy introspection see it."""
    pol = get_policy("daemon").with_(controller="adaptive")
    assert pol.components()["controller"] == "adaptive"
    assert get_policy("daemon").components()["controller"] is None
