"""Dry-run machinery integration test: reduced configs, small fake-device
meshes, run in subprocesses (device count locks at jax init).  Covers the
same code path as the production 16x16 / 2x16x16 batch."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_dryrun(arch: str, cell: str, mesh: str, tmp, extra=()):
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(REPO, "src"),
        REPRO_XLA_FLAGS="--xla_force_host_platform_device_count=16",
    )
    out = os.path.join(tmp, "dr")
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
        "--cell", cell, "--mesh", mesh, "--reduced", "--out", out, *extra,
    ]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-2500:]
    tag = f"{arch}_{cell}_{mesh}_baseline_reduced"
    rec = json.loads(open(os.path.join(out, f"{tag}.json")).read())
    assert rec["ok"], rec.get("error")
    return rec


@pytest.mark.slow
@pytest.mark.parametrize("mesh", ["2x2", "2x2x2"])
def test_dryrun_train_single_and_multipod(mesh, tmp_path):
    rec = run_dryrun("minicpm-2b", "train_4k", mesh, str(tmp_path))
    assert rec["flops"] > 0 and rec["hbm_bytes"] > 0
    assert rec["bottleneck"] in ("compute", "memory", "collective")
    assert rec["memory_analysis"]["temp_size_in_bytes"] > 0


@pytest.mark.slow
def test_dryrun_decode_cache_shard_modes(tmp_path):
    a = run_dryrun("qwen3-14b", "decode_32k", "2x2", str(tmp_path))
    b = run_dryrun("qwen3-14b", "decode_32k", "2x2", str(tmp_path) + "b",
                   extra=("--cache-shard", "dh"))
    assert a["ok"] and b["ok"]


@pytest.mark.slow
def test_dryrun_moe_and_ssm_families(tmp_path):
    run_dryrun("deepseek-v2-lite-16b", "train_4k", "2x2", str(tmp_path))
    run_dryrun("falcon-mamba-7b", "decode_32k", "2x2", str(tmp_path) + "f")
