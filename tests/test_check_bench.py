"""Unit tests for the benchmark-regression gate's compare path
(benchmarks/check_bench.py) — pure-dict ledgers, no simulation."""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.check_bench import compare


def _section(derived, axes=None, spec=None):
    return {"axes": axes or {"scheme": ["page", "daemon"]},
            "spec": spec or {"n_accesses": 1000},
            "derived": derived}


def _statuses(baseline, fresh, tol=0.05, sections=None):
    return {(name, key): (base, new, rel, status)
            for name, key, base, new, rel, status
            in compare(baseline, fresh, tol, sections)}


def test_matching_geomeans_are_ok():
    b = {"fig": _section({"daemon_vs_page_geomean": 3.0})}
    f = {"fig": _section({"daemon_vs_page_geomean": 3.1})}
    (_, _, rel, status) = _statuses(b, f)[("fig", "daemon_vs_page_geomean")]
    assert status == "ok" and abs(rel - (0.1 / 3.0)) < 1e-12


def test_drift_beyond_tolerance_is_regression():
    b = {"fig": _section({"daemon_vs_page_geomean": 3.0})}
    f = {"fig": _section({"daemon_vs_page_geomean": 4.0})}
    assert _statuses(b, f)[("fig", "daemon_vs_page_geomean")][3] == "regression"


def test_both_zero_is_ok_not_inf():
    """base == new == 0 must compare as rel = 0.0 / 'ok' — the legacy
    base-falsy branch produced rel = inf and flagged a perfect match as a
    regression."""
    b = {"fig": _section({"daemon_vs_page_geomean@x=1": 0.0})}
    f = {"fig": _section({"daemon_vs_page_geomean@x=1": 0.0})}
    (_, _, rel, status) = _statuses(b, f)[("fig", "daemon_vs_page_geomean@x=1")]
    assert status == "ok"
    assert rel == 0.0


def test_zero_base_nonzero_fresh_still_fails():
    """0 -> nonzero genuinely diverged: rel stays inf and fails the gate."""
    b = {"fig": _section({"daemon_vs_page_geomean": 0.0})}
    f = {"fig": _section({"daemon_vs_page_geomean": 2.0})}
    (_, _, rel, status) = _statuses(b, f)[("fig", "daemon_vs_page_geomean")]
    assert status == "regression"
    assert rel == float("inf")


def test_spec_mismatch_refuses_comparison():
    b = {"fig": _section({"daemon_vs_page_geomean": 3.0},
                         spec={"n_accesses": 1000})}
    f = {"fig": _section({"daemon_vs_page_geomean": 3.0},
                         spec={"n_accesses": 2000})}
    assert _statuses(b, f)[("fig", "spec")][3] == "spec-mismatch"


def test_missing_section_and_key_fail():
    b = {"fig": _section({"daemon_vs_page_geomean": 3.0,
                          "policy_vs_page_geomean@x": 1.5})}
    assert _statuses(b, {})[("fig", "")][3] == "missing-section"
    f = {"fig": _section({"daemon_vs_page_geomean": 3.0})}
    assert _statuses(b, f)[("fig", "policy_vs_page_geomean@x")][3] == \
        "missing-key"


def test_ungated_keys_are_ignored():
    b = {"fig": _section({"daemon_vs_page_geomean": 3.0, "wall_s": 10.0})}
    f = {"fig": _section({"daemon_vs_page_geomean": 3.0, "wall_s": 99.0})}
    assert ("fig", "wall_s") not in _statuses(b, f)
