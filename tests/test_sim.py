"""Faithful-reproduction tests for the DaeMon DS simulator (paper §3/§4):
scheme ordering, robustness (daemon never loses to page), the headline
geomean claims, and Fig-4-style sweeps."""

from repro.core.sim import (
    SimConfig, fig4_bottom, fig4_top, paper_claims, run_one,
)

N = 15_000  # accesses per thread-group: fast but statistically stable


def test_local_is_fastest():
    for w in ("pr", "st"):
        loc = run_one(w, "local", n_accesses=N)
        for s in ("page", "cacheline", "both", "daemon"):
            m = run_one(w, s, n_accesses=N)
            assert m.cycles >= loc.cycles * 0.99, (w, s)


def test_page_free_matches_local_performance_class():
    """'Page moved for free' ~= locality benefits without transfer cost."""
    for w in ("pr", "dr"):
        free = run_one(w, "page_free", n_accesses=N)
        page = run_one(w, "page", n_accesses=N)
        assert free.cycles < page.cycles


def test_line_friendly_vs_page_friendly_classes():
    """Paper Fig 2 structure: some workloads prefer line movement (irregular:
    pr, nw, dr-as-delaunay) while others prefer pages (streaming: st) — no
    fixed granularity is robust across the suite."""
    cfg = SimConfig(link_bw_frac=0.25)
    for w, line_wins in (("pr", True), ("nw", True), ("dr", True), ("st", False)):
        line = run_one(w, "cacheline", cfg, n_accesses=N)
        page = run_one(w, "page", cfg, n_accesses=N)
        assert (line.cycles < page.cycles) == line_wins, w


def test_daemon_robust_never_loses_to_page():
    """The robustness claim: daemon <= ~page on EVERY workload and network."""
    for bw in (0.5, 0.25, 0.125):
        cfg = SimConfig(link_bw_frac=bw)
        for w in ("pr", "bf", "ts", "nw", "dr", "pf", "st", "ml"):
            page = run_one(w, "page", cfg, n_accesses=N)
            dae = run_one(w, "daemon", cfg, n_accesses=N)
            assert dae.cycles <= page.cycles * 1.05, (w, bw)


def test_daemon_beats_naive_both():
    """Decoupled queues beat single-FIFO line+page on line-friendly loads."""
    cfg = SimConfig(link_bw_frac=0.125)
    for w in ("pr", "nw"):
        both = run_one(w, "both", cfg, n_accesses=N)
        dae = run_one(w, "daemon", cfg, n_accesses=N)
        assert dae.cycles < both.cycles, w


def test_compression_reduces_network_bytes():
    cfg = SimConfig(link_bw_frac=0.125)
    on = run_one("pr", "daemon", cfg, n_accesses=N)
    off = run_one("pr", "daemon", cfg.with_(compress=False), n_accesses=N)
    assert on.net_bytes < off.net_bytes
    assert on.bytes_saved_compression > 0
    assert on.cycles <= off.cycles * 1.02


def test_paper_claims():
    """Headline: paper reports 2.39x perf / 3.06x access-cost geomean for
    daemon over page.  Our synthetic-trace reproduction must land in the
    same regime (>=1.8x both, bracketing the claims across 1/4-1/8 bw)."""
    r = paper_claims(n_accesses=N)
    assert r["perf_speedup_geomean"] >= 1.8, r
    assert r["access_cost_reduction_geomean"] >= 1.8, r
    # tighter band at the congested end
    assert r["per_bw"][0.125]["perf"] >= 2.2, r


def test_fig4_top_bandwidth_trend():
    """Gains grow as network bandwidth shrinks (paper Fig 4 top)."""
    rows = fig4_top(workloads=("pr",), bw_fracs=(0.5, 0.125), n_mcs_list=(1,),
                    n_accesses=N)
    by_bw = {r["bw_frac"]: r["speedup"] for r in rows}
    assert by_bw[0.125] > by_bw[0.5]


def test_fig4_top_more_mcs_reduce_pressure():
    rows = fig4_top(workloads=("pr",), bw_fracs=(0.125,), n_mcs_list=(1, 4),
                    n_accesses=N)
    by_mc = {r["n_mcs"]: r["speedup"] for r in rows}
    # with 4x aggregate bandwidth the page scheme suffers less -> smaller gap
    assert by_mc[4] <= by_mc[1] * 1.1


def test_fig4_bottom_multijob():
    rows = fig4_bottom(workloads=("pr", "nw"), n_jobs=2, n_accesses=N)
    for r in rows:
        assert r["speedup"] >= 1.0, r


def test_determinism():
    a = run_one("pr", "daemon", n_accesses=5000, seed=3)
    b = run_one("pr", "daemon", n_accesses=5000, seed=3)
    assert a.cycles == b.cycles and a.net_bytes == b.net_bytes
